//! The artifact manifest: the contract between the build-time python
//! layer (`python/compile/aot.py`) and the rust runtime. Describes, per
//! model variant, the HLO files plus the exact flat signature of the
//! train/init executables (state array order/shapes, batch inputs,
//! scalar hyperparameters, metric outputs).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{parse, Json};

/// Shape of one state array (a parameter or velocity tensor).
#[derive(Clone, Debug)]
pub struct ArraySpec {
    /// Array name from the python exporter.
    pub name: String,
    /// Dimension extents.
    pub shape: Vec<usize>,
}

impl ArraySpec {
    /// Element count (scalars count as 1).
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Shape + dtype of one batch input to the train executable.
#[derive(Clone, Debug)]
pub struct InputSpec {
    /// Input name from the python exporter.
    pub name: String,
    /// Dimension extents.
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
}

impl InputSpec {
    /// Element count (scalars count as 1).
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Everything the runtime needs to know about one compiled variant.
#[derive(Clone, Debug)]
pub struct ModelManifest {
    /// Variant name (e.g. "mlp_relu", "tlm_gelu").
    pub name: String,
    /// HLO text file of the fused train step.
    pub train_hlo: String,
    /// HLO text file of the state initializer.
    pub init_hlo: String,
    /// Parameter arrays; the executable's state is params then
    /// velocities, each in this order with identical shapes.
    pub state: Vec<ArraySpec>,
    /// Batch inputs in executable argument order.
    pub batch_inputs: Vec<InputSpec>,
    /// Scalar hyperparameter names fed each step (e.g. lr, momentum).
    pub scalars: Vec<String>,
    /// Output metric names; `loss` first by convention.
    pub metrics: Vec<String>,
    /// Total trainable parameters.
    pub param_count: u64,
    /// "mlp" | "transformer_lm".
    pub kind: String,
    /// Activation the variant was compiled with.
    pub activation: String,
    /// Batch size baked into the executable.
    pub batch: usize,
    /// Raw `meta` object from the manifest (vocab size, etc.).
    pub meta: Json,
}

impl ModelManifest {
    /// Number of state arrays in the executable (params + velocities).
    pub fn num_state_arrays(&self) -> usize {
        self.state.len() * 2
    }

    /// Total f32 elements across the full state.
    pub fn state_elements(&self) -> usize {
        self.state.iter().map(|a| a.elements()).sum::<usize>() * 2
    }

    /// Number of train-executable outputs: state' + loss + extra metrics.
    pub fn num_outputs(&self) -> usize {
        self.num_state_arrays() + self.metrics.len()
    }
}

/// The full artifact manifest: directory + per-variant entries.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    /// Artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Model variants by name.
    pub models: BTreeMap<String, ModelManifest>,
}

fn arr_usize(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("expected array"))?
        .iter()
        .map(|v| v.as_u64().map(|u| u as usize).ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

impl Manifest {
    /// Parse `<dir>/manifest.json` written by `python/compile/aot.py`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let root = parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        let mut models = BTreeMap::new();
        let model_obj = root
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| anyhow!("manifest missing models"))?;
        for (name, m) in model_obj {
            let strf = |k: &str| -> Result<String> {
                m.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| anyhow!("model {name}: missing {k}"))
            };
            let state = m
                .get("state")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("model {name}: missing state"))?
                .iter()
                .map(|a| {
                    Ok(ArraySpec {
                        name: a.get("name").and_then(|v| v.as_str()).unwrap_or("").into(),
                        shape: arr_usize(a.get("shape").ok_or_else(|| anyhow!("shape"))?)?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let batch_inputs = m
                .get("batch_inputs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("model {name}: missing batch_inputs"))?
                .iter()
                .map(|a| {
                    Ok(InputSpec {
                        name: a.get("name").and_then(|v| v.as_str()).unwrap_or("").into(),
                        shape: arr_usize(a.get("shape").ok_or_else(|| anyhow!("shape"))?)?,
                        dtype: a.get("dtype").and_then(|v| v.as_str()).unwrap_or("f32").into(),
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let strings = |k: &str| -> Vec<String> {
                m.get(k)
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                    .unwrap_or_default()
            };
            models.insert(
                name.clone(),
                ModelManifest {
                    name: name.clone(),
                    train_hlo: strf("train_hlo")?,
                    init_hlo: strf("init_hlo")?,
                    state,
                    batch_inputs,
                    scalars: strings("scalars"),
                    metrics: strings("metrics"),
                    param_count: m.get("param_count").and_then(|v| v.as_u64()).unwrap_or(0),
                    kind: m
                        .get("meta.kind")
                        .and_then(|v| v.as_str())
                        .unwrap_or("unknown")
                        .into(),
                    activation: m
                        .get("meta.activation")
                        .and_then(|v| v.as_str())
                        .unwrap_or("linear")
                        .into(),
                    batch: m.get("meta.batch").and_then(|v| v.as_u64()).unwrap_or(0) as usize,
                    meta: m.get("meta").cloned().unwrap_or(Json::Null),
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    /// Look up a variant by name, with a helpful error listing options.
    pub fn model(&self, name: &str) -> Result<&ModelManifest> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model variant {name:?}; have {:?}", self.models.keys()))
    }

    /// Default artifacts directory (repo-root/artifacts), overridable
    /// via TUNE_ARTIFACTS.
    pub fn default_dir() -> PathBuf {
        if let Ok(d) = std::env::var("TUNE_ARTIFACTS") {
            return PathBuf::from(d);
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_generated_manifest_if_present() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.models.contains_key("mlp_relu"), "{:?}", m.models.keys());
        let mlp = m.model("mlp_relu").unwrap();
        assert_eq!(mlp.kind, "mlp");
        assert_eq!(mlp.state.len(), 6); // 3 layers x (w, b)
        assert_eq!(mlp.num_state_arrays(), 12);
        assert_eq!(mlp.scalars, vec!["lr", "momentum"]);
        assert_eq!(mlp.metrics[0], "loss");
        let tlm = m.model("tlm_gelu").unwrap();
        assert_eq!(tlm.kind, "transformer_lm");
        assert!(tlm.param_count > 100_000);
    }

    #[test]
    fn missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
