//! The Ray-like execution substrate Tune depends on (Moritz et al. 2017),
//! rebuilt in-process: resource vectors, a multi-node cluster, two-level
//! (local-first / spill-over) placement, an object store with transfer
//! accounting, and deterministic fault injection.
//!
//! The coordinator only touches this layer through resource leases,
//! placements, and object ids — the same narrow surface Tune uses of
//! real Ray — so trial scheduling logic is oblivious to whether trials
//! run on the discrete-event executor (virtual time) or on real threads
//! driving PJRT executables.

pub mod autoscale;
pub mod cluster;
pub mod fault;
pub mod object_store;
pub mod placement;
pub mod profile;
pub mod resources;

pub use autoscale::{AutoscaleAction, AutoscalePolicy, Autoscaler, HwInputs, NodeTemplate};
pub use cluster::{Cluster, LeaseId, Node, NodeId, Utilization};
pub use fault::{FaultInjector, FaultPlan};
pub use object_store::{ObjectId, ObjectStore};
pub use placement::{Placement, PlacementStats, TwoLevelScheduler};
pub use profile::{opportunity_cost, shape_key, ShapeFactors, ThroughputProfiler};
pub use resources::Resources;
