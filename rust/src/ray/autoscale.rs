//! Elastic cluster autoscaling, the "scale to the workload" half of the
//! paper's resource model: trials declare demands, the cluster grows
//! when demand outstrips it and shrinks when nodes go idle.
//!
//! The policy is deliberately simple and fully deterministic (ticks are
//! counted in coordinator events, not wall time, so sim and pool runs
//! make identical decisions):
//!
//! * **Scale up** — after `scale_up_after` consecutive ticks in which a
//!   pending trial failed placement *and* the node template could hold
//!   its demand, add one template node (bounded by `max_nodes`).
//! * **Scale down** — a node whose busiest-dimension utilization stays
//!   at or below `scale_down_util` for `scale_down_after` consecutive
//!   ticks is *drained*: placement stops targeting it, the coordinator
//!   preempts its remaining trials checkpoint-then-requeue at their
//!   next result, and the node retires once empty — a shrink never
//!   loses a trial. At most `min_nodes` survivors are never drained.
//!
//! The autoscaler only decides; the coordinator (which owns leases and
//! checkpoints) applies [`AutoscaleAction`]s.

use std::collections::{BTreeMap, BTreeSet};

use super::cluster::{Cluster, NodeId};
use super::resources::Resources;

/// Tolerance for the scale-down utilization comparison.
const UTIL_EPS: f64 = 1e-9;

/// A purchasable node shape: capacity plus its virtual $/hour price.
/// The autoscaler's scale-up step picks among these (SHADHO's
/// cost-aware policy); the legacy single-template path is a one-entry
/// list at price zero.
#[derive(Clone, Debug, PartialEq)]
pub struct NodeTemplate {
    /// Capacity of a node bought from this template.
    pub shape: Resources,
    /// Virtual dollars accrued per hour of *alive* time on the virtual
    /// clock (draining nodes still bill until they retire).
    pub price_per_hour: f64,
}

impl NodeTemplate {
    /// A free template — the shape-only legacy form.
    pub fn free(shape: Resources) -> Self {
        NodeTemplate { shape, price_per_hour: 0.0 }
    }
    /// Knob validation shared by the spec file and CLI paths.
    pub fn validate(&self) -> Result<(), String> {
        self.shape.validate_demand().map_err(|e| format!("template shape: {e}"))?;
        if !self.price_per_hour.is_finite() || self.price_per_hour < 0.0 {
            return Err(format!(
                "template price_per_hour must be finite and >= 0, got {}",
                self.price_per_hour
            ));
        }
        Ok(())
    }
}

/// Knobs for the elastic autoscaler.
#[derive(Clone, Debug)]
pub struct AutoscalePolicy {
    /// Capacity of every node added on scale-up.
    pub node_template: Resources,
    /// Priced node shapes scale-up may choose among. Empty means the
    /// legacy single-shape path: `node_template` at price zero.
    pub templates: Vec<NodeTemplate>,
    /// Never drain below this many alive, non-draining nodes.
    pub min_nodes: usize,
    /// Never grow past this many alive nodes.
    pub max_nodes: usize,
    /// Consecutive unplaceable-pressure ticks before adding a node.
    pub scale_up_after: u64,
    /// Consecutive low-utilization ticks before draining a node.
    pub scale_down_after: u64,
    /// Utilization (busiest dimension, fraction of capacity) at or
    /// below which a node counts as scale-down eligible. 0.0 drains
    /// only fully idle nodes; e.g. 0.2 also consolidates stragglers off
    /// nearly-empty nodes (their trials are preempted with a checkpoint
    /// and requeued elsewhere).
    pub scale_down_util: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            node_template: Resources::cpu(8.0),
            templates: Vec::new(),
            min_nodes: 1,
            max_nodes: 8,
            scale_up_after: 4,
            scale_down_after: 200,
            scale_down_util: 0.0,
        }
    }
}

impl AutoscalePolicy {
    /// Validate knob ranges — the single rule set shared by the CLI
    /// flags and the spec-file `autoscale` block.
    pub fn validate(&self) -> Result<(), String> {
        self.node_template
            .validate_demand()
            .map_err(|e| format!("node template: {e}"))?;
        for (i, t) in self.templates.iter().enumerate() {
            t.validate().map_err(|e| format!("templates[{i}]: {e}"))?;
        }
        if self.scale_up_after == 0 || self.scale_down_after == 0 {
            return Err("scale_up_after and scale_down_after must be >= 1".into());
        }
        if !(0.0..=1.0).contains(&self.scale_down_util) {
            return Err("scale_down_util must be in [0, 1]".into());
        }
        if self.min_nodes > self.max_nodes {
            return Err(format!(
                "min_nodes {} exceeds max_nodes {}",
                self.min_nodes, self.max_nodes
            ));
        }
        Ok(())
    }
}

/// What the autoscaler wants done this tick (at most one action —
/// gentle, deterministic steps).
#[derive(Clone, Debug, PartialEq)]
pub enum AutoscaleAction {
    /// Nothing to do.
    None,
    /// Add a node from this template (shape + price).
    AddNode(NodeTemplate),
    /// Drain this node toward retirement (preempt its trials as they
    /// report, retire it once empty).
    Drain(NodeId),
}

/// Optional hardware-aware signals the runner feeds into a tick. The
/// default (both absent) reproduces the cost/throughput-blind policy
/// exactly, so the PR-5 decision trajectories are unchanged unless the
/// experiment opts in.
#[derive(Clone, Debug, Default)]
pub struct HwInputs {
    /// Learned fleet throughput score per policy template, in
    /// [`Autoscaler::templates`] order (predicted steps/sec for the
    /// current workload mix on that shape). When present, AddNode picks
    /// the template maximizing score ÷ price instead of the first fit.
    pub template_scores: Option<Vec<f64>>,
    /// Remaining virtual budget (`budget.max_cost - accrued`). At or
    /// below zero, scale-up is suppressed entirely: a node bought now
    /// could never be paid for.
    pub cost_headroom: Option<f64>,
}

/// Deterministic elastic autoscaler: counts queue-pressure and idle
/// streaks in coordinator ticks and emits one [`AutoscaleAction`] at a
/// time. Owned by the runner; one per experiment (clusters are
/// per-experiment, like all other runner state).
///
/// Streaks are tracked *lazily*: instead of walking every node each
/// tick to bump its counter, the autoscaler records the logical tick at
/// which a node's current low-utilization run began (`low_since`) and
/// re-classifies nodes only when the cluster's change epoch moves —
/// node utilizations cannot change without a cluster mutation, so a
/// quiet tick costs O(1) regardless of node count. The observable
/// decision sequence is identical to the eager per-tick walk (the unit
/// tests below pin it tick by tick).
#[derive(Clone, Debug)]
pub struct Autoscaler {
    /// The policy being executed.
    pub policy: AutoscalePolicy,
    /// Normalized purchasable templates: `policy.templates`, or the
    /// legacy `[node_template @ $0]` when that list is empty. Fixed at
    /// construction so every tick indexes one canonical order.
    templates: Vec<NodeTemplate>,
    /// Consecutive ticks with unplaceable pending demand.
    pressure: u64,
    /// Logical scale-down clock. Advances only on ticks that reach the
    /// scale-down section — ticks that early-return (zombie sweep,
    /// scale-up) freeze every streak, exactly as the eager walk did.
    down_clock: u64,
    /// node -> `down_clock` value at which its current low streak was 0
    /// (so streak = `down_clock - low_since`). Absent = streak 0.
    low_since: BTreeMap<NodeId, u64>,
    /// Every node id ever classified — busy nodes snapshot as streak 0.
    known: BTreeSet<NodeId>,
    /// `down_clock` -> (node, low_since) entries whose streak reaches
    /// `scale_down_after` at that clock value; stale entries (the node
    /// left or restarted its low run) are dropped on promotion.
    upcoming: BTreeMap<u64, Vec<(NodeId, u64)>>,
    /// Nodes whose streak already crossed the threshold, in id order —
    /// the candidate scan only ever looks here.
    eligible: BTreeSet<NodeId>,
    /// Eligible nodes skipped by the last-home guard; re-examined when
    /// the cluster epoch or the demand shape changes.
    parked: BTreeSet<NodeId>,
    /// Cluster change epoch at the last reclassification.
    seen_epoch: Option<u64>,
    /// Demand shape seen last tick (last-home verdicts depend on it).
    last_demand: Option<Resources>,
}

impl Autoscaler {
    /// A fresh autoscaler for `policy`.
    pub fn new(policy: AutoscalePolicy) -> Self {
        let templates = if policy.templates.is_empty() {
            vec![NodeTemplate::free(policy.node_template.clone())]
        } else {
            policy.templates.clone()
        };
        Autoscaler {
            policy,
            templates,
            pressure: 0,
            down_clock: 0,
            low_since: BTreeMap::new(),
            known: BTreeSet::new(),
            upcoming: BTreeMap::new(),
            eligible: BTreeSet::new(),
            parked: BTreeSet::new(),
            seen_epoch: None,
            last_demand: None,
        }
    }

    /// Could adding template nodes ever help `demand`? (Used by the
    /// coordinator to decide whether an unplaceable backlog is worth
    /// waiting out or hopeless.) Empty draining nodes do not occupy
    /// headroom: the zombie sweep retires them on the very next tick —
    /// counting them would make a run resumed from a mid-drain snapshot
    /// at `max_nodes` look permanently stuck and finalize with its
    /// rolled-back trials unrun.
    pub fn can_grow(&self, cluster: &Cluster, demand: &Resources) -> bool {
        self.headroom(cluster) && self.template_fits(demand)
    }

    /// Zombie-aware node headroom — the ONE growth-bound check, shared
    /// by [`can_grow`](Self::can_grow) and the tick's scale-up branch.
    /// (They used to disagree: tick counted empty draining zombies
    /// against `max_nodes` while `can_grow` did not, so the runner's
    /// hopeless-backlog guard waited forever on an AddNode that tick
    /// refused to emit.)
    fn headroom(&self, cluster: &Cluster) -> bool {
        let occupying = cluster.utilization().nodes_alive - cluster.draining_empty_count();
        occupying < self.policy.max_nodes
    }

    /// The normalized purchasable template list (never empty), in the
    /// order [`HwInputs::template_scores`] is expected to follow.
    pub fn templates(&self) -> &[NodeTemplate] {
        &self.templates
    }

    /// True when at least one template shape could hold `demand`.
    fn template_fits(&self, demand: &Resources) -> bool {
        self.templates.iter().any(|t| t.shape.fits(demand))
    }

    /// Choose the template for a scale-up. Cost headroom at or below
    /// zero vetoes the add outright. With learned scores the pick
    /// maximizes predicted steps/sec per dollar (ties keep the earliest
    /// template, so equal-value templates resolve deterministically);
    /// without scores it is the first template that fits — the legacy
    /// single-template behaviour.
    fn pick_template(&self, demand: &Resources, hw: &HwInputs) -> Option<NodeTemplate> {
        if hw.cost_headroom.is_some_and(|h| h <= 0.0) {
            return None;
        }
        let mut best: Option<(usize, f64)> = None;
        for (i, t) in self.templates.iter().enumerate() {
            if !t.shape.fits(demand) {
                continue;
            }
            match &hw.template_scores {
                None => return Some(t.clone()),
                Some(scores) => {
                    // Score per dollar; the epsilon keeps free templates
                    // finite (they win any tie on throughput alone).
                    let value =
                        scores.get(i).copied().unwrap_or(0.0) / t.price_per_hour.max(1e-6);
                    if best.map_or(true, |(_, b)| {
                        crate::util::order::asc(value, b) == std::cmp::Ordering::Greater
                    }) {
                        best = Some((i, value));
                    }
                }
            }
        }
        best.map(|(i, _)| self.templates[i].clone())
    }

    /// Reset a node's low-utilization streak — the coordinator calls
    /// this when `add_node` reuses a retired slot, so the fresh node
    /// does not inherit its predecessor's idle history.
    pub fn reset_streak(&mut self, node: NodeId) {
        self.known.insert(node);
        self.low_since.remove(&node);
        self.eligible.remove(&node);
        self.parked.remove(&node);
        // Any upcoming entry is now stale (low_since mismatch) and will
        // be dropped on promotion; reclassify on the next tick.
        self.seen_epoch = None;
    }

    /// The eager-walk streak value for `node` at the current clock.
    fn streak_of(&self, node: NodeId) -> u64 {
        self.low_since.get(&node).map_or(0, |s| self.down_clock - s)
    }

    /// Serialize mutable state (pressure + per-node streaks) for the
    /// experiment snapshot, so a resumed run continues the same
    /// scale-up/scale-down trajectory instead of starting cold. The
    /// format is the eager streak map — lazy bookkeeping never leaks
    /// into snapshots.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("pressure", Json::Num(self.pressure as f64)),
            (
                "low_util",
                Json::Obj(
                    self.known
                        .iter()
                        .map(|n| (n.to_string(), Json::Num(self.streak_of(*n) as f64)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Rebuild state from an [`Autoscaler::snapshot`] value. Streak
    /// dynamics only depend on clock *differences*, so the clock
    /// restarts at the largest restored streak.
    pub fn restore(&mut self, snap: &crate::util::json::Json) -> Result<(), String> {
        self.pressure = snap
            .get("pressure")
            .and_then(|v| v.as_u64())
            .ok_or("autoscaler snapshot: bad pressure")?;
        let streaks: BTreeMap<NodeId, u64> = snap
            .get("low_util")
            .and_then(|m| m.as_obj())
            .ok_or("autoscaler snapshot: bad streaks")?
            .iter()
            .map(|(k, v)| Some((k.parse::<NodeId>().ok()?, v.as_u64()?)))
            .collect::<Option<_>>()
            .ok_or("autoscaler snapshot: bad streak entry")?;
        let clock = streaks.values().copied().max().unwrap_or(0);
        self.down_clock = clock;
        self.known = streaks.keys().copied().collect();
        self.low_since = streaks
            .iter()
            .filter(|(_, s)| **s > 0)
            .map(|(n, s)| (*n, clock - s))
            .collect();
        self.upcoming.clear();
        self.eligible.clear();
        self.parked.clear();
        self.seen_epoch = None;
        self.last_demand = None;
        Ok(())
    }

    /// Re-derive low/busy membership from the cluster — the only
    /// O(nodes) step, run when the cluster's change epoch moved (i.e.
    /// at most once per actual mutation, not once per tick).
    fn reclassify(&mut self, cluster: &Cluster) {
        for n in cluster.alive_nodes() {
            if n.draining {
                self.low_since.remove(&n.id);
                continue;
            }
            self.known.insert(n.id);
            if n.utilization() <= self.policy.scale_down_util + UTIL_EPS {
                // Newly low: streak counts 1 on this tick, like the
                // eager walk's 0 -> 1 bump.
                self.low_since.entry(n.id).or_insert(self.down_clock - 1);
            } else {
                self.low_since.remove(&n.id);
            }
        }
        let nodes = &cluster.nodes;
        self.low_since.retain(|id, _| {
            let n = &nodes[*id as usize];
            n.alive && !n.draining
        });
        self.upcoming.clear();
        self.eligible.clear();
        self.parked.clear();
        for (&id, &since) in &self.low_since {
            let due = since + self.policy.scale_down_after;
            if due <= self.down_clock {
                self.eligible.insert(id);
            } else {
                self.upcoming.entry(due).or_default().push((id, since));
            }
        }
    }

    /// Advance one tick. `unplaceable` reports whether the coordinator
    /// failed to place a pending demand of shape `demand` since the
    /// last tick. Returns at most one action for the coordinator to
    /// apply.
    pub fn tick(
        &mut self,
        cluster: &Cluster,
        unplaceable: bool,
        demand: &Resources,
    ) -> AutoscaleAction {
        self.tick_hw(cluster, unplaceable, demand, &HwInputs::default())
    }

    /// [`tick`](Self::tick) with optional hardware-aware inputs (learned
    /// template scores, remaining cost budget) from the runner.
    pub fn tick_hw(
        &mut self,
        cluster: &Cluster,
        unplaceable: bool,
        demand: &Resources,
        hw: &HwInputs,
    ) -> AutoscaleAction {
        // Pressure accounting comes FIRST: a tick is a tick, whatever
        // else it does. (The zombie sweep below used to early-return
        // before this point, silently swallowing the tick's pressure
        // increment — a resume from mid-drain then needed extra ticks
        // beyond `scale_up_after` to grow.)
        let mut want_add = false;
        if unplaceable && self.template_fits(demand) {
            self.pressure += 1;
            want_add = self.pressure >= self.policy.scale_up_after && self.headroom(cluster);
        } else {
            self.pressure = 0;
        }

        // Zombie sweep: a draining node whose leases are gone (e.g. a
        // fault cleared them) must still retire — re-issue the drain so
        // the coordinator completes it. O(1) via the cluster's index.
        if let Some(id) = cluster.first_zombie() {
            return AutoscaleAction::Drain(id);
        }

        // Scale up on sustained pressure a template could relieve.
        if want_add {
            if let Some(t) = self.pick_template(demand, hw) {
                self.pressure = 0;
                return AutoscaleAction::AddNode(t);
            }
        }

        // Scale down: drain the first node (id order, deterministic)
        // whose low-utilization streak crossed the threshold, keeping
        // at least `min_nodes` non-draining survivors — and never the
        // demand's last possible home: retiring the only shape that
        // fits `demand` (with a template that cannot replace it) would
        // strand every preempted/pending trial of that shape.
        self.down_clock += 1;
        let epoch = cluster.change_epoch();
        if self.seen_epoch != Some(epoch) {
            self.reclassify(cluster);
            self.seen_epoch = Some(epoch);
        }
        if self.last_demand.as_ref() != Some(demand) {
            // Last-home verdicts depend on the demand shape: recheck
            // parked nodes when it changes.
            let parked = std::mem::take(&mut self.parked);
            self.eligible.extend(parked);
            self.last_demand = Some(demand.clone());
        }
        // Promote nodes whose streak crosses the threshold this tick.
        while let Some((&due, _)) = self.upcoming.first_key_value() {
            if due > self.down_clock {
                break;
            }
            for (id, since) in self.upcoming.remove(&due).unwrap_or_default() {
                if self.low_since.get(&id) == Some(&since) {
                    self.eligible.insert(id);
                }
            }
        }
        let u = cluster.utilization();
        let survivors = u.nodes_alive - u.nodes_draining;
        let mut chosen: Option<(NodeId, f64)> = None;
        let mut park = Vec::new();
        if survivors > self.policy.min_nodes {
            let template_helps = self.template_fits(demand);
            for &id in &self.eligible {
                let n = cluster.node(id);
                let last_home = n.total.fits(demand)
                    && !template_helps
                    && !cluster
                        .alive_nodes()
                        .any(|m| m.id != id && !m.draining && m.total.fits(demand));
                if last_home {
                    park.push(id);
                    continue;
                }
                // Among eligible candidates, drain the most expensive
                // node first (cost-aware shrink); strictly-greater keeps
                // the lowest id on ties, so at uniform prices this is
                // byte-identical to the old first-eligible pick.
                if chosen.map_or(true, |(_, b)| {
                    crate::util::order::asc(n.price_per_hour, b) == std::cmp::Ordering::Greater
                }) {
                    chosen = Some((id, n.price_per_hour));
                }
            }
        }
        let chosen = chosen.map(|(id, _)| id);
        for id in park {
            self.eligible.remove(&id);
            self.parked.insert(id);
        }
        if let Some(id) = chosen {
            // Streak restarts at zero, exactly as the eager walk reset
            // the drained candidate's counter (the node is still low:
            // it re-qualifies after another full streak if the
            // coordinator ignores the drain).
            self.eligible.remove(&id);
            let since = self.down_clock;
            self.low_since.insert(id, since);
            self.upcoming
                .entry(since + self.policy.scale_down_after)
                .or_default()
                .push((id, since));
            return AutoscaleAction::Drain(id);
        }
        AutoscaleAction::None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy(up: u64, down: u64, util: f64, min: usize, max: usize) -> AutoscalePolicy {
        AutoscalePolicy {
            node_template: Resources::cpu_gpu(8.0, 4.0),
            templates: Vec::new(),
            min_nodes: min,
            max_nodes: max,
            scale_up_after: up,
            scale_down_after: down,
            scale_down_util: util,
        }
    }

    #[test]
    fn sustained_pressure_adds_a_node() {
        let mut a = Autoscaler::new(policy(3, 1000, 0.0, 1, 4));
        let mut c = Cluster::uniform(1, Resources::cpu_gpu(8.0, 4.0));
        c.lease(0, Resources::cpu_gpu(8.0, 4.0)); // full
        let d = Resources::cpu_gpu(1.0, 0.5);
        assert_eq!(a.tick(&c, true, &d), AutoscaleAction::None);
        assert_eq!(a.tick(&c, true, &d), AutoscaleAction::None);
        match a.tick(&c, true, &d) {
            AutoscaleAction::AddNode(t) => {
                assert_eq!(t.shape, Resources::cpu_gpu(8.0, 4.0));
                assert_eq!(t.price_per_hour, 0.0);
            }
            other => panic!("{other:?}"),
        }
        // Pressure resets after an add.
        assert_eq!(a.tick(&c, true, &d), AutoscaleAction::None);
    }

    #[test]
    fn pressure_ignored_when_template_cannot_help() {
        let mut a = Autoscaler::new(policy(1, 1000, 0.0, 1, 4));
        let c = Cluster::uniform(1, Resources::cpu(1.0));
        // Demand exceeds even the template: adding nodes is pointless.
        let d = Resources::cpu_gpu(1.0, 9.0);
        for _ in 0..5 {
            assert_eq!(a.tick(&c, true, &d), AutoscaleAction::None);
        }
        assert!(!a.can_grow(&c, &d));
        assert!(a.can_grow(&c, &Resources::cpu_gpu(1.0, 0.5)));
    }

    #[test]
    fn max_nodes_caps_growth() {
        let mut a = Autoscaler::new(policy(1, 1000, 0.0, 1, 2));
        let c = Cluster::uniform(2, Resources::cpu(1.0));
        let d = Resources::cpu(1.0);
        for _ in 0..5 {
            assert_eq!(a.tick(&c, true, &d), AutoscaleAction::None);
        }
    }

    #[test]
    fn idle_streak_drains_above_min_nodes() {
        let mut a = Autoscaler::new(policy(100, 3, 0.0, 1, 4));
        let mut c = Cluster::uniform(2, Resources::cpu(4.0));
        c.lease(0, Resources::cpu(2.0)); // node 0 busy, node 1 idle
        let d = Resources::cpu(1.0);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::Drain(1));
        c.begin_drain(1);
        c.retire_node(1);
        // min_nodes = 1: the survivor is never drained even when idle.
        let mut b = Autoscaler::new(policy(100, 1, 1.0, 1, 4));
        let c2 = Cluster::uniform(1, Resources::cpu(4.0));
        for _ in 0..5 {
            assert_eq!(b.tick(&c2, false, &d), AutoscaleAction::None);
        }
    }

    #[test]
    fn low_util_threshold_consolidates_stragglers() {
        let mut a = Autoscaler::new(policy(100, 2, 0.3, 0, 4));
        let mut c = Cluster::uniform(1, Resources::cpu_gpu(8.0, 4.0));
        c.lease(0, Resources::cpu_gpu(1.0, 0.5)); // 12.5% busy: a straggler
        let d = Resources::cpu(1.0);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::Drain(0));
    }

    #[test]
    fn busy_node_resets_its_streak() {
        let mut a = Autoscaler::new(policy(100, 2, 0.0, 0, 4));
        let mut c = Cluster::uniform(1, Resources::cpu(4.0));
        let d = Resources::cpu(1.0);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None);
        let l = c.lease(0, Resources::cpu(1.0));
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None); // reset
        c.release(0, l);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::Drain(0));
    }

    #[test]
    fn never_drains_the_demands_last_home() {
        // CPU-only template cannot replace the lone GPU node, so the
        // GPU node is protected however idle it is; the idle CPU node
        // drains instead — and after that, nothing does.
        let mut p = policy(100, 2, 1.0, 0, 4);
        p.node_template = Resources::cpu(8.0);
        let mut a = Autoscaler::new(p);
        let mut c = Cluster::heterogeneous(vec![
            Resources::cpu_gpu(8.0, 4.0),
            Resources::cpu(8.0),
        ]);
        let d = Resources::cpu_gpu(1.0, 0.5);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::Drain(1));
        c.begin_drain(1);
        c.retire_node(1);
        for _ in 0..5 {
            assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None);
        }
        // A GPU-bearing template lifts the protection.
        let mut b = Autoscaler::new(policy(100, 2, 1.0, 0, 4));
        let c2 = Cluster::uniform(1, Resources::cpu_gpu(8.0, 4.0));
        assert_eq!(b.tick(&c2, false, &d), AutoscaleAction::None);
        assert_eq!(b.tick(&c2, false, &d), AutoscaleAction::Drain(0));
    }

    #[test]
    fn snapshot_roundtrip_preserves_pressure_and_streaks() {
        let mut a = Autoscaler::new(policy(5, 10, 0.0, 1, 4));
        let c = Cluster::uniform(2, Resources::cpu(4.0));
        let d = Resources::cpu(1.0);
        for _ in 0..3 {
            a.tick(&c, true, &d); // pressure 3, idle streaks 3
        }
        let text = a.snapshot().to_string();
        let mut b = Autoscaler::new(policy(5, 10, 0.0, 1, 4));
        b.restore(&crate::util::json::parse(&text).unwrap()).unwrap();
        // Same continuation: two more pressure ticks trigger the add in
        // both the original and the restored instance.
        assert_eq!(a.tick(&c, true, &d), b.tick(&c, true, &d));
        let (x, y) = (a.tick(&c, true, &d), b.tick(&c, true, &d));
        assert_eq!(x, y);
        assert!(matches!(x, AutoscaleAction::AddNode(_)));
        // Streak reset hook (used when add_node reuses a retired slot).
        a.reset_streak(0);
        assert_eq!(a.snapshot().get("low_util").unwrap().get("0").unwrap().as_u64(), Some(0));
    }

    #[test]
    fn empty_draining_node_is_swept() {
        let mut a = Autoscaler::new(policy(100, 1000, 0.0, 0, 4));
        let mut c = Cluster::uniform(2, Resources::cpu(4.0));
        c.begin_drain(0);
        let d = Resources::cpu(1.0);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::Drain(0));
    }

    #[test]
    fn empty_draining_nodes_do_not_occupy_headroom() {
        // A mid-drain snapshot restored at max_nodes: the empty
        // draining zombie retires on the next tick, so growth must
        // still be considered possible — otherwise the resumed run
        // finalizes with its trials unrun.
        let a = Autoscaler::new(policy(2, 1000, 0.0, 0, 2));
        let mut c = Cluster::uniform(2, Resources::cpu_gpu(8.0, 4.0));
        let d = Resources::cpu_gpu(1.0, 0.5);
        assert!(!a.can_grow(&c, &d)); // genuinely full
        c.begin_drain(0);
        assert!(a.can_grow(&c, &d)); // zombie: about to retire
        // A draining node still holding leases DOES occupy headroom.
        c.lease(1, Resources::cpu(1.0));
        c.begin_drain(1);
        assert!(a.can_grow(&c, &d));
        let _ = c.lease(0, Resources::cpu(1.0));
        assert!(!a.can_grow(&c, &d));
    }

    #[test]
    fn zombie_at_max_nodes_does_not_stall_scale_up() {
        // Regression: resume-from-mid-drain at max_nodes with an
        // unplaceable backlog. The empty draining zombie must neither
        // occupy headroom nor swallow pressure ticks — AddNode must
        // arrive within scale_up_after ticks of sustained pressure.
        let mut a = Autoscaler::new(policy(2, 1000, 0.0, 0, 2));
        let mut c = Cluster::uniform(2, Resources::cpu_gpu(8.0, 4.0));
        c.lease(1, Resources::cpu_gpu(8.0, 4.0)); // node 1 full
        c.begin_drain(0); // node 0: empty draining zombie (mid-drain resume)
        let d = Resources::cpu_gpu(1.0, 0.5);
        // The hopeless-backlog guard and the tick must agree growth is
        // possible — this disagreement was the bug.
        assert!(a.can_grow(&c, &d));
        // Tick 1: the sweep re-issues the drain, but the pressure tick
        // still counts.
        assert_eq!(a.tick(&c, true, &d), AutoscaleAction::Drain(0));
        c.retire_node(0);
        // Tick 2 (= scale_up_after): pressure crosses the threshold and
        // the add fires. The old code needed a third tick.
        assert!(matches!(a.tick(&c, true, &d), AutoscaleAction::AddNode(_)));
    }

    #[test]
    fn cost_aware_pick_prefers_cheaper_equal_shape() {
        let mut p = policy(1, 1000, 0.0, 0, 4);
        p.templates = vec![
            NodeTemplate { shape: Resources::cpu(4.0), price_per_hour: 8.0 },
            NodeTemplate { shape: Resources::cpu(4.0), price_per_hour: 1.0 },
        ];
        assert!(p.validate().is_ok());
        let mut c = Cluster::uniform(1, Resources::cpu(1.0));
        c.lease(0, Resources::cpu(1.0)); // full
        let d = Resources::cpu(1.0);
        // Equal throughput scores: price decides — the $1 shape wins.
        let hw = HwInputs {
            template_scores: Some(vec![1.0, 1.0]),
            cost_headroom: Some(100.0),
        };
        let mut a = Autoscaler::new(p.clone());
        match a.tick_hw(&c, true, &d, &hw) {
            AutoscaleAction::AddNode(t) => assert_eq!(t.price_per_hour, 1.0),
            other => panic!("{other:?}"),
        }
        // Without learned scores the pick is the first fitting template
        // (legacy order), whatever its price.
        let mut b = Autoscaler::new(p);
        match b.tick_hw(&c, true, &d, &HwInputs::default()) {
            AutoscaleAction::AddNode(t) => assert_eq!(t.price_per_hour, 8.0),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn exhausted_cost_headroom_vetoes_growth() {
        let mut a = Autoscaler::new(policy(1, 1000, 0.0, 0, 4));
        let mut c = Cluster::uniform(1, Resources::cpu(1.0));
        c.lease(0, Resources::cpu(1.0));
        let d = Resources::cpu(1.0);
        let broke = HwInputs { template_scores: None, cost_headroom: Some(0.0) };
        assert_eq!(a.tick_hw(&c, true, &d, &broke), AutoscaleAction::None);
        assert_eq!(a.tick_hw(&c, true, &d, &broke), AutoscaleAction::None);
        // Pressure was retained, not reset: the moment budget reappears
        // the add fires on the very next tick.
        let funded = HwInputs { template_scores: None, cost_headroom: Some(5.0) };
        assert!(matches!(a.tick_hw(&c, true, &d, &funded), AutoscaleAction::AddNode(_)));
    }

    #[test]
    fn drains_most_expensive_eligible_first() {
        // Two equally idle nodes; the cost-aware shrink retires the
        // expensive one. At uniform prices the lowest id still wins
        // (the legacy deterministic order).
        let mut a = Autoscaler::new(policy(100, 2, 1.0, 0, 4));
        let c = Cluster::heterogeneous_priced(vec![
            (Resources::cpu_gpu(8.0, 4.0), 1.0),
            (Resources::cpu_gpu(8.0, 4.0), 5.0),
        ]);
        let d = Resources::cpu(1.0);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::None);
        assert_eq!(a.tick(&c, false, &d), AutoscaleAction::Drain(1));
    }

    #[test]
    fn policy_validation_rejects_bad_knobs() {
        assert!(AutoscalePolicy::default().validate().is_ok());
        let bad_util = AutoscalePolicy { scale_down_util: 2.0, ..Default::default() };
        assert!(bad_util.validate().is_err());
        let zero_tick = AutoscalePolicy { scale_up_after: 0, ..Default::default() };
        assert!(zero_tick.validate().is_err());
        let inverted = AutoscalePolicy { min_nodes: 9, max_nodes: 2, ..Default::default() };
        assert!(inverted.validate().is_err());
        let nan_template =
            AutoscalePolicy { node_template: Resources::cpu(f64::NAN), ..Default::default() };
        assert!(nan_template.validate().is_err());
        let neg_price = AutoscalePolicy {
            templates: vec![NodeTemplate { shape: Resources::cpu(4.0), price_per_hour: -1.0 }],
            ..Default::default()
        };
        assert!(neg_price.validate().is_err());
        let nan_price = AutoscalePolicy {
            templates: vec![NodeTemplate { shape: Resources::cpu(4.0), price_per_hour: f64::NAN }],
            ..Default::default()
        };
        assert!(nan_price.validate().is_err());
    }
}
