//! Nodes and the cluster they form.
//!
//! The substrate Tune runs on (the paper runs on Ray): a set of nodes
//! with resource capacities. Nodes can be added (autoscaling) or killed
//! (fault injection); killing a node surfaces the set of lease-holders
//! that were placed there so the coordinator can reschedule them.

use std::collections::{BTreeMap, BTreeSet};

use super::resources::Resources;

/// Index of a node within the cluster.
pub type NodeId = u32;
/// Handle for one granted resource lease.
pub type LeaseId = u64;

/// One machine: total capacity, what is still free, and who holds leases.
#[derive(Clone, Debug)]
pub struct Node {
    /// This node's id (its index in the cluster).
    pub id: NodeId,
    /// Full capacity.
    pub total: Resources,
    /// Capacity not currently leased.
    pub available: Resources,
    /// False once killed by fault injection (until restarted).
    pub alive: bool,
    /// Being removed by the autoscaler: the placement layer stops
    /// putting new work here; once its last lease is released the
    /// coordinator retires it ([`Cluster::retire_node`]).
    pub draining: bool,
    /// Permanently removed by an autoscale shrink. Unlike a
    /// fault-killed node it never restarts, its capacity does not count
    /// toward feasibility, and its slot is reused by the next
    /// [`Cluster::add_node`].
    pub retired: bool,
    /// Live leases placed on this node: lease -> demand.
    pub leases: BTreeMap<LeaseId, Resources>,
    /// Virtual $/hour this node bills while alive (draining included —
    /// a node costs money until it actually retires). 0 for free nodes,
    /// which is every node outside cost-aware experiments.
    pub price_per_hour: f64,
}

impl Node {
    /// A fresh, alive node with `total` capacity (price zero).
    pub fn new(id: NodeId, total: Resources) -> Self {
        Node {
            id,
            available: total.clone(),
            total,
            alive: true,
            draining: false,
            retired: false,
            leases: BTreeMap::new(),
            price_per_hour: 0.0,
        }
    }

    /// Fraction of CPU capacity currently leased.
    pub fn utilization_cpu(&self) -> f64 {
        if self.total.cpu == 0.0 {
            0.0
        } else {
            1.0 - self.available.cpu / self.total.cpu
        }
    }

    /// Fraction of GPU capacity currently leased (0 on GPU-less nodes).
    pub fn utilization_gpu(&self) -> f64 {
        if self.total.gpu == 0.0 {
            0.0
        } else {
            1.0 - self.available.gpu / self.total.gpu
        }
    }

    /// The busiest dimension's utilization — what the autoscaler's
    /// scale-down threshold compares against (a node with a busy GPU or
    /// a saturated custom resource is not "idle" just because its CPUs
    /// are free).
    pub fn utilization(&self) -> f64 {
        let mut u = self.utilization_cpu().max(self.utilization_gpu());
        for (k, total) in &self.total.custom {
            if *total > 0.0 {
                let avail = self.available.custom.get(k).copied().unwrap_or(0.0);
                u = u.max(1.0 - avail / total);
            }
        }
        u
    }
}

/// Aggregate CPU/GPU utilization across alive nodes — the cheap (`Copy`,
/// allocation-free) snapshot the runner refreshes on every lease change
/// and exposes through `SchedulerCtx`, `tune status` and run summaries.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Utilization {
    /// CPU cores currently leased across alive nodes.
    pub cpu_used: f64,
    /// Total CPU cores on alive nodes.
    pub cpu_total: f64,
    /// GPU devices currently leased across alive nodes.
    pub gpu_used: f64,
    /// Total GPU devices on alive nodes.
    pub gpu_total: f64,
    /// Alive nodes (draining included — they still hold leases).
    pub nodes_alive: usize,
    /// Alive nodes currently draining toward retirement.
    pub nodes_draining: usize,
}

impl Utilization {
    /// Leased fraction of CPU capacity (0 when the cluster has none).
    pub fn cpu_frac(&self) -> f64 {
        if self.cpu_total == 0.0 {
            0.0
        } else {
            self.cpu_used / self.cpu_total
        }
    }

    /// Leased fraction of GPU capacity (0 when the cluster has none).
    pub fn gpu_frac(&self) -> f64 {
        if self.gpu_total == 0.0 {
            0.0
        } else {
            self.gpu_used / self.gpu_total
        }
    }
}

/// A set of nodes trials are placed onto.
///
/// Alongside the node table the cluster maintains incremental indices —
/// a cached [`Utilization`] aggregate, the sorted alive-id list, the
/// set of empty draining nodes and three change epochs — so the
/// coordinator's per-event reads (`utilization()`, `alive_ids()`,
/// `first_zombie()`, the placement fail-fast) are O(1) instead of
/// O(nodes). Every index is maintained by the mutating methods below;
/// mutate nodes only through those methods, never via the `nodes` field
/// directly.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// All nodes, indexed by `NodeId`. Read-only outside this module:
    /// direct mutation would desynchronize the incremental indices.
    pub nodes: Vec<Node>,
    next_lease: LeaseId,
    /// Incrementally maintained aggregate over alive nodes.
    util: Utilization,
    /// Ids of alive nodes, ascending — the same order
    /// [`Cluster::alive_nodes`] yields, so fault-victim selection over
    /// this slice replays identically.
    alive_ids: Vec<NodeId>,
    /// Alive draining nodes with no leases left ("zombies" awaiting
    /// retirement), ascending.
    draining_empty: BTreeSet<NodeId>,
    /// Bumped on every observable mutation; consumers (autoscaler) use
    /// it to skip per-node rescans when nothing changed.
    change_epoch: u64,
    /// Bumped whenever placeable free capacity may have increased
    /// (release on a non-draining alive node, restart, add). The
    /// placement layer's negative cache is keyed on this.
    grow_epoch: u64,
    /// Bumped when the set of node shapes eligible for
    /// [`Cluster::any_node_fits`] changes (add / retire).
    shape_epoch: u64,
    /// Incrementally maintained sum of `price_per_hour` over alive
    /// nodes — the instantaneous virtual burn rate the runner
    /// integrates over the virtual clock.
    price_rate: f64,
}

impl Cluster {
    /// An empty cluster.
    pub fn new() -> Self {
        Cluster {
            nodes: Vec::new(),
            next_lease: 1,
            util: Utilization::default(),
            alive_ids: Vec::new(),
            draining_empty: BTreeSet::new(),
            change_epoch: 0,
            grow_epoch: 0,
            shape_epoch: 0,
            price_rate: 0.0,
        }
    }

    /// `n` identical nodes of `each` capacity.
    pub fn uniform(n: usize, each: Resources) -> Self {
        let mut c = Cluster::new();
        for _ in 0..n {
            c.add_node(each.clone());
        }
        c
    }

    /// A heterogeneous node set: one node per capacity vector, in order
    /// (e.g. two 4-GPU trainers plus two CPU-only preprocessing nodes).
    pub fn heterogeneous(shapes: Vec<Resources>) -> Self {
        let mut c = Cluster::new();
        for s in shapes {
            c.add_node(s);
        }
        c
    }

    /// A heterogeneous node set with per-node $/hour prices — the
    /// cost-aware twin of [`Cluster::heterogeneous`].
    pub fn heterogeneous_priced(shapes: Vec<(Resources, f64)>) -> Self {
        let mut c = Cluster::new();
        for (s, price) in shapes {
            c.add_node_priced(s, price);
        }
        c
    }

    /// Add a free node with `total` capacity (autoscaling); returns its
    /// id. See [`Cluster::add_node_priced`].
    pub fn add_node(&mut self, total: Resources) -> NodeId {
        self.add_node_priced(total, 0.0)
    }

    /// Add a node with `total` capacity billing `price_per_hour`;
    /// returns its id. Reuses the first retired slot if any, so scale
    /// up/down churn never grows the node table without bound
    /// (fault-killed nodes are NOT reused — they may restart with their
    /// original capacity).
    pub fn add_node_priced(&mut self, total: Resources, price_per_hour: f64) -> NodeId {
        let id = if let Some(slot) = self.nodes.iter().position(|n| n.retired) {
            let id = slot as NodeId;
            self.nodes[slot] = Node::new(id, total);
            id
        } else {
            let id = self.nodes.len() as NodeId;
            self.nodes.push(Node::new(id, total));
            id
        };
        let n = &mut self.nodes[id as usize];
        n.price_per_hour = price_per_hour;
        self.price_rate += price_per_hour;
        let n = &self.nodes[id as usize];
        self.util.cpu_total += n.total.cpu;
        self.util.gpu_total += n.total.gpu;
        self.util.nodes_alive += 1;
        self.alive_insert(id);
        self.change_epoch += 1;
        self.grow_epoch += 1;
        self.shape_epoch += 1;
        id
    }

    fn alive_insert(&mut self, id: NodeId) {
        if let Err(pos) = self.alive_ids.binary_search(&id) {
            self.alive_ids.insert(pos, id);
        }
    }

    fn alive_remove(&mut self, id: NodeId) {
        if let Ok(pos) = self.alive_ids.binary_search(&id) {
            self.alive_ids.remove(pos);
        }
    }

    /// Borrow a node by id.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    /// Grant a lease of `demand` on `node`. Caller must have verified
    /// the fit (the placement layer does); returns the lease id.
    pub fn lease(&mut self, node: NodeId, demand: Resources) -> LeaseId {
        let n = &mut self.nodes[node as usize];
        debug_assert!(n.alive && n.available.fits(&demand));
        n.available.acquire(&demand);
        self.util.cpu_used += demand.cpu;
        self.util.gpu_used += demand.gpu;
        let id = self.next_lease;
        self.next_lease += 1;
        let was_empty = n.leases.is_empty();
        n.leases.insert(id, demand);
        if n.draining && was_empty {
            self.draining_empty.remove(&node);
        }
        self.change_epoch += 1;
        id
    }

    /// Release a lease; no-op if the node already died (its resources
    /// are gone with it).
    pub fn release(&mut self, node: NodeId, lease: LeaseId) {
        let n = &mut self.nodes[node as usize];
        if let Some(demand) = n.leases.remove(&lease) {
            if n.alive {
                n.available.release(&demand);
                self.util.cpu_used -= demand.cpu;
                self.util.gpu_used -= demand.gpu;
                if n.draining {
                    if n.leases.is_empty() {
                        self.draining_empty.insert(node);
                    }
                } else {
                    // Capacity a future placement could use came free.
                    self.grow_epoch += 1;
                }
            }
            self.change_epoch += 1;
        }
    }

    /// Kill a node; returns the lease ids that were running there.
    pub fn kill_node(&mut self, node: NodeId) -> Vec<LeaseId> {
        let n = &mut self.nodes[node as usize];
        if n.alive {
            self.util.cpu_total -= n.total.cpu;
            self.util.gpu_total -= n.total.gpu;
            self.util.cpu_used -= n.total.cpu - n.available.cpu;
            self.util.gpu_used -= n.total.gpu - n.available.gpu;
            self.util.nodes_alive -= 1;
            if n.draining {
                self.util.nodes_draining -= 1;
            }
            self.price_rate -= n.price_per_hour;
        }
        let n = &mut self.nodes[node as usize];
        n.alive = false;
        n.available = Resources::default();
        self.alive_remove(node);
        self.draining_empty.remove(&node);
        self.change_epoch += 1;
        let n = &mut self.nodes[node as usize];
        std::mem::take(&mut n.leases).into_keys().collect()
    }

    /// Restart a dead node with its original capacity. Retired nodes
    /// never come back (their slot belongs to the next `add_node`).
    pub fn restart_node(&mut self, node: NodeId) {
        let n = &mut self.nodes[node as usize];
        if !n.alive && !n.retired {
            n.alive = true;
            n.available = n.total.clone();
            self.util.cpu_total += n.total.cpu;
            self.util.gpu_total += n.total.gpu;
            self.util.nodes_alive += 1;
            self.price_rate += n.price_per_hour;
            let draining = n.draining;
            if draining {
                // The drain flag survives a kill; it comes back as an
                // empty draining node the autoscaler can sweep.
                self.util.nodes_draining += 1;
                self.draining_empty.insert(node);
            } else {
                self.grow_epoch += 1;
            }
            self.alive_insert(node);
            self.change_epoch += 1;
        }
    }

    /// Start draining a node: the placement layer stops placing new work
    /// on it, existing leases keep running until the coordinator sheds
    /// them (checkpoint-then-requeue). Idempotent.
    pub fn begin_drain(&mut self, node: NodeId) {
        let n = &mut self.nodes[node as usize];
        if !n.draining {
            n.draining = true;
            if n.alive {
                self.util.nodes_draining += 1;
                if self.nodes[node as usize].leases.is_empty() {
                    self.draining_empty.insert(node);
                }
            }
            self.change_epoch += 1;
        }
    }

    /// Gracefully remove a drained node (autoscale shrink). Unlike
    /// [`Cluster::kill_node`] this is only legal once every lease is
    /// gone — the coordinator preempts lease-holders first, so a shrink
    /// never loses a trial.
    pub fn retire_node(&mut self, node: NodeId) {
        let n = &mut self.nodes[node as usize];
        debug_assert!(n.leases.is_empty(), "retiring node {node} with live leases");
        if n.alive {
            self.util.cpu_total -= n.total.cpu;
            self.util.gpu_total -= n.total.gpu;
            self.util.cpu_used -= n.total.cpu - n.available.cpu;
            self.util.gpu_used -= n.total.gpu - n.available.gpu;
            self.util.nodes_alive -= 1;
            if n.draining {
                self.util.nodes_draining -= 1;
            }
            self.price_rate -= n.price_per_hour;
        }
        let n = &mut self.nodes[node as usize];
        n.alive = false;
        n.draining = false;
        n.retired = true;
        n.available = Resources::default();
        self.alive_remove(node);
        self.draining_empty.remove(&node);
        self.change_epoch += 1;
        self.shape_epoch += 1;
    }

    /// Aggregate utilization snapshot over alive nodes — an O(1) read
    /// of the incrementally maintained aggregate.
    pub fn utilization(&self) -> Utilization {
        self.util
    }

    /// Recompute the aggregate by scanning every node — the reference
    /// the cached value is checked against (tests / debug audits only).
    pub fn recompute_utilization(&self) -> Utilization {
        let mut u = Utilization::default();
        for n in self.alive_nodes() {
            u.cpu_total += n.total.cpu;
            u.gpu_total += n.total.gpu;
            u.cpu_used += n.total.cpu - n.available.cpu;
            u.gpu_used += n.total.gpu - n.available.gpu;
            u.nodes_alive += 1;
            if n.draining {
                u.nodes_draining += 1;
            }
        }
        u
    }

    /// Ids of alive nodes in ascending order — same order (and
    /// therefore same deterministic fault-victim stream) as
    /// [`Cluster::alive_nodes`], without building a fresh `Vec` per
    /// event.
    pub fn alive_ids(&self) -> &[NodeId] {
        &self.alive_ids
    }

    /// Lowest-id alive draining node with no leases left, if any — the
    /// O(1) zombie sweep the autoscaler runs every tick.
    pub fn first_zombie(&self) -> Option<NodeId> {
        self.draining_empty.iter().next().copied()
    }

    /// Alive draining nodes with no leases (candidates for retirement).
    pub fn draining_empty_count(&self) -> usize {
        self.draining_empty.len()
    }

    /// Instantaneous virtual burn rate: sum of $/hour over alive nodes
    /// (an O(1) read of the incrementally maintained sum). The runner
    /// integrates this over the virtual clock into `cost_accrued`.
    pub fn price_rate(&self) -> f64 {
        self.price_rate
    }

    /// Bumped on every observable mutation (see field docs).
    pub fn change_epoch(&self) -> u64 {
        self.change_epoch
    }

    /// Bumped whenever placeable free capacity may have increased.
    pub fn grow_epoch(&self) -> u64 {
        self.grow_epoch
    }

    /// Bumped when the shape set behind [`Cluster::any_node_fits`]
    /// changes.
    pub fn shape_epoch(&self) -> u64 {
        self.shape_epoch
    }

    /// Could `demand` ever run on this cluster's node shapes? Checks
    /// *total* capacities (dead nodes may restart, busy ones free up)
    /// but skips retired nodes (gone for good) — the fail-fast
    /// feasibility test behind `resources_per_trial` validation, not an
    /// admission check.
    pub fn any_node_fits(&self, demand: &Resources) -> bool {
        self.nodes.iter().any(|n| !n.retired && n.total.fits(demand))
    }

    /// Serialize the node table (shapes + alive/draining/retired flags)
    /// for the experiment snapshot. Leases and free capacity are NOT
    /// recorded: a resumed run rolls every running trial back and
    /// re-leases on relaunch, so nodes restore at full availability.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Arr(
            self.nodes
                .iter()
                .map(|n| {
                    Json::obj(vec![
                        ("total", n.total.to_json()),
                        ("alive", Json::Bool(n.alive)),
                        ("draining", Json::Bool(n.draining)),
                        ("retired", Json::Bool(n.retired)),
                        ("price", Json::Num(n.price_per_hour)),
                    ])
                })
                .collect(),
        )
    }

    /// Rebuild a cluster from [`Cluster::snapshot`]: every node at full
    /// availability with no leases, preserving shapes and
    /// alive/draining/retired flags — so a resumed autoscaled
    /// experiment continues on the cluster it actually grew, not the
    /// initial shape.
    pub fn restore_nodes(snap: &crate::util::json::Json) -> Result<Cluster, String> {
        let list = snap.as_arr().ok_or("cluster snapshot: expected node array")?;
        let mut c = Cluster::new();
        for (i, nj) in list.iter().enumerate() {
            let flag = |k: &str| nj.get(k).and_then(|v| v.as_bool()).unwrap_or(false);
            let total = nj
                .get("total")
                .and_then(Resources::from_json)
                .ok_or("cluster snapshot: bad node capacity")?;
            // Push directly (not add_node: it would reuse a slot we
            // just restored as retired and corrupt the id mapping).
            let mut n = Node::new(i as NodeId, total);
            n.alive = flag("alive");
            n.draining = flag("draining");
            n.retired = flag("retired");
            // Absent in pre-cost snapshots: free node.
            n.price_per_hour = nj.get("price").and_then(|v| v.as_f64()).unwrap_or(0.0);
            if !n.alive {
                n.available = Resources::default();
            }
            c.nodes.push(n);
        }
        c.rebuild_index();
        Ok(c)
    }

    /// Recompute every incremental index from the node table. Called
    /// once after restore (indices are never persisted); everywhere
    /// else the mutating methods keep them current.
    fn rebuild_index(&mut self) {
        self.util = self.recompute_utilization();
        self.price_rate = self.alive_nodes().map(|n| n.price_per_hour).sum();
        self.alive_ids = self.nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
        self.draining_empty = self
            .nodes
            .iter()
            .filter(|n| n.alive && n.draining && n.leases.is_empty())
            .map(|n| n.id)
            .collect();
        self.change_epoch += 1;
        self.grow_epoch += 1;
        self.shape_epoch += 1;
    }

    /// Verify every incremental index against a full recompute;
    /// returns a description of the first mismatch. Test support.
    #[doc(hidden)]
    pub fn debug_check(&self) -> Result<(), String> {
        let want = self.recompute_utilization();
        let close = |a: f64, b: f64| (a - b).abs() < 1e-6;
        if !(close(self.util.cpu_used, want.cpu_used)
            && close(self.util.cpu_total, want.cpu_total)
            && close(self.util.gpu_used, want.gpu_used)
            && close(self.util.gpu_total, want.gpu_total)
            && self.util.nodes_alive == want.nodes_alive
            && self.util.nodes_draining == want.nodes_draining)
        {
            return Err(format!("cached util {:?} != recomputed {:?}", self.util, want));
        }
        let alive: Vec<NodeId> = self.nodes.iter().filter(|n| n.alive).map(|n| n.id).collect();
        if self.alive_ids != alive {
            return Err(format!("alive_ids {:?} != recomputed {:?}", self.alive_ids, alive));
        }
        let zombies: BTreeSet<NodeId> = self
            .nodes
            .iter()
            .filter(|n| n.alive && n.draining && n.leases.is_empty())
            .map(|n| n.id)
            .collect();
        if self.draining_empty != zombies {
            return Err(format!(
                "draining_empty {:?} != recomputed {:?}",
                self.draining_empty, zombies
            ));
        }
        let rate: f64 = self.alive_nodes().map(|n| n.price_per_hour).sum();
        if !close(self.price_rate, rate) {
            return Err(format!("cached price_rate {} != recomputed {rate}", self.price_rate));
        }
        if !self.check_invariants() {
            return Err("per-node lease accounting violated".into());
        }
        Ok(())
    }

    /// Iterator over nodes that are currently alive.
    pub fn alive_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.alive)
    }

    /// Sum of free capacity across alive nodes.
    pub fn total_available(&self) -> Resources {
        let mut r = Resources::default();
        for n in self.alive_nodes() {
            r.release(&n.available);
        }
        r
    }

    /// Accounting invariant: per-node available + sum(leases) == total.
    pub fn check_invariants(&self) -> bool {
        self.nodes.iter().all(|n| {
            if !n.alive {
                return true;
            }
            let mut acc = n.available.clone();
            for d in n.leases.values() {
                acc.release(d);
            }
            (acc.cpu - n.total.cpu).abs() < 1e-6
                && (acc.gpu - n.total.gpu).abs() < 1e-6
                && n.available.is_valid()
        })
    }
}

impl Default for Cluster {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_and_release() {
        let mut c = Cluster::uniform(2, Resources::cpu_gpu(4.0, 1.0));
        let l = c.lease(0, Resources::cpu(2.0));
        assert_eq!(c.node(0).available.cpu, 2.0);
        assert!(c.check_invariants());
        c.release(0, l);
        assert_eq!(c.node(0).available.cpu, 4.0);
    }

    #[test]
    fn kill_node_returns_leases() {
        let mut c = Cluster::uniform(1, Resources::cpu(4.0));
        let l1 = c.lease(0, Resources::cpu(1.0));
        let l2 = c.lease(0, Resources::cpu(1.0));
        let mut killed = c.kill_node(0);
        killed.sort();
        assert_eq!(killed, vec![l1, l2]);
        assert!(!c.node(0).alive);
        // Release after death is a no-op, not a panic.
        c.release(0, l1);
        c.restart_node(0);
        assert_eq!(c.node(0).available.cpu, 4.0);
        assert!(c.check_invariants());
    }

    #[test]
    fn total_available_sums_alive_only() {
        let mut c = Cluster::uniform(3, Resources::cpu(2.0));
        c.kill_node(1);
        assert_eq!(c.total_available().cpu, 4.0);
    }

    #[test]
    fn heterogeneous_shapes_and_feasibility() {
        let c = Cluster::heterogeneous(vec![
            Resources::cpu_gpu(8.0, 4.0),
            Resources::cpu(8.0),
        ]);
        assert_eq!(c.nodes.len(), 2);
        assert!(c.any_node_fits(&Resources::cpu_gpu(1.0, 0.5)));
        assert!(c.any_node_fits(&Resources::cpu(8.0)));
        assert!(!c.any_node_fits(&Resources::cpu_gpu(0.0, 9.0)));
        assert!(!c.any_node_fits(&Resources::cpu(16.0)));
    }

    #[test]
    fn drain_then_retire_lifecycle() {
        let mut c = Cluster::uniform(2, Resources::cpu_gpu(4.0, 2.0));
        let l = c.lease(0, Resources::cpu_gpu(1.0, 0.5));
        c.begin_drain(0);
        assert!(c.node(0).alive && c.node(0).draining);
        c.release(0, l);
        c.retire_node(0);
        assert!(!c.node(0).alive && !c.node(0).draining && c.node(0).retired);
        assert_eq!(c.total_available().cpu, 4.0);
        assert!(c.check_invariants());
        // Retired nodes never restart and never count for feasibility.
        c.restart_node(0);
        assert!(!c.node(0).alive);
        assert!(!Cluster::uniform(0, Resources::default())
            .any_node_fits(&Resources::cpu(1.0)));
        c.retire_node(1);
        assert!(!c.any_node_fits(&Resources::cpu(1.0)));
    }

    #[test]
    fn add_node_reuses_retired_slots_only() {
        let mut c = Cluster::uniform(2, Resources::cpu(4.0));
        c.kill_node(0); // fault-killed: may restart, slot NOT reusable
        c.retire_node(1);
        let id = c.add_node(Resources::cpu_gpu(8.0, 2.0));
        assert_eq!(id, 1, "retired slot must be reused");
        assert_eq!(c.nodes.len(), 2);
        assert!(c.node(1).alive && !c.node(1).retired);
        assert_eq!(c.node(1).total, Resources::cpu_gpu(8.0, 2.0));
        // No retired slot left: append.
        let id = c.add_node(Resources::cpu(2.0));
        assert_eq!(id, 2);
        assert_eq!(c.nodes.len(), 3);
        // The fault-killed node is still restartable.
        c.restart_node(0);
        assert!(c.node(0).alive);
    }

    #[test]
    fn cluster_snapshot_roundtrip_preserves_shapes_and_flags() {
        let mut c = Cluster::heterogeneous(vec![
            Resources::cpu_gpu(8.0, 4.0).with_custom("tpu", 2.0),
            Resources::cpu(8.0),
            Resources::cpu(4.0),
        ]);
        c.lease(0, Resources::cpu_gpu(1.0, 0.5)); // leases are NOT persisted
        c.begin_drain(1);
        c.retire_node(2);
        let text = c.snapshot().to_string();
        let back =
            Cluster::restore_nodes(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.nodes.len(), 3);
        assert_eq!(back.node(0).total, c.node(0).total);
        // Restored at full availability, no leases.
        assert_eq!(back.node(0).available, back.node(0).total);
        assert!(back.node(0).leases.is_empty());
        assert!(back.node(1).draining && back.node(1).alive);
        assert!(back.node(2).retired && !back.node(2).alive);
        assert!(back.check_invariants());
        // A retired slot restored as retired is still reusable.
        assert_eq!(back.clone().add_node(Resources::cpu(1.0)), 2);
    }

    #[test]
    fn utilization_tracks_leases_and_draining() {
        let mut c = Cluster::heterogeneous(vec![
            Resources::cpu_gpu(8.0, 4.0),
            Resources::cpu(8.0),
        ]);
        c.lease(0, Resources::cpu_gpu(2.0, 1.0));
        c.begin_drain(1);
        let u = c.utilization();
        assert_eq!(u.cpu_total, 16.0);
        assert_eq!(u.gpu_total, 4.0);
        assert!((u.cpu_frac() - 2.0 / 16.0).abs() < 1e-9);
        assert!((u.gpu_frac() - 0.25).abs() < 1e-9);
        assert_eq!(u.nodes_alive, 2);
        assert_eq!(u.nodes_draining, 1);
        assert!((c.node(0).utilization() - 0.25).abs() < 1e-9);
    }

    #[test]
    fn incremental_indices_track_full_lifecycle() {
        let mut c = Cluster::heterogeneous(vec![
            Resources::cpu_gpu(8.0, 4.0),
            Resources::cpu(8.0),
            Resources::cpu(4.0),
        ]);
        c.debug_check().unwrap();
        let l0 = c.lease(0, Resources::cpu_gpu(2.0, 1.0));
        let l1 = c.lease(1, Resources::cpu(3.0));
        c.debug_check().unwrap();
        assert_eq!(c.utilization(), c.recompute_utilization());
        assert_eq!(c.alive_ids(), &[0, 1, 2]);
        c.begin_drain(1);
        assert_eq!(c.first_zombie(), None, "draining node still holds a lease");
        c.release(1, l1);
        assert_eq!(c.first_zombie(), Some(1));
        c.debug_check().unwrap();
        c.retire_node(1);
        assert_eq!(c.alive_ids(), &[0, 2]);
        assert_eq!(c.first_zombie(), None);
        c.kill_node(2);
        assert_eq!(c.alive_ids(), &[0]);
        c.restart_node(2);
        assert_eq!(c.alive_ids(), &[0, 2]);
        c.release(0, l0);
        c.add_node(Resources::cpu(16.0));
        c.debug_check().unwrap();
        assert_eq!(c.utilization(), c.recompute_utilization());
    }

    #[test]
    fn grow_epoch_moves_only_when_capacity_can_appear() {
        let mut c = Cluster::uniform(2, Resources::cpu(4.0));
        let e0 = c.grow_epoch();
        let l = c.lease(0, Resources::cpu(4.0));
        assert_eq!(c.grow_epoch(), e0, "acquiring capacity must not invalidate fail-fast");
        c.release(0, l);
        assert!(c.grow_epoch() > e0, "released capacity must invalidate fail-fast");
        let e1 = c.grow_epoch();
        let l = c.lease(1, Resources::cpu(1.0));
        c.begin_drain(1);
        c.release(1, l);
        assert_eq!(c.grow_epoch(), e1, "draining capacity is not placeable");
        c.kill_node(0);
        assert_eq!(c.grow_epoch(), e1);
        c.restart_node(0);
        assert!(c.grow_epoch() > e1, "a restarted node is placeable again");
    }

    #[test]
    fn restored_cluster_rebuilds_indices() {
        let mut c = Cluster::heterogeneous(vec![Resources::cpu(8.0), Resources::cpu(4.0)]);
        c.lease(0, Resources::cpu(2.0));
        c.begin_drain(1);
        let back = Cluster::restore_nodes(
            &crate::util::json::parse(&c.snapshot().to_string()).unwrap(),
        )
        .unwrap();
        back.debug_check().unwrap();
        assert_eq!(back.alive_ids(), &[0, 1]);
        // Leases are not persisted, so the drained node restores empty.
        assert_eq!(back.first_zombie(), Some(1));
        assert_eq!(back.utilization(), back.recompute_utilization());
    }

    #[test]
    fn price_rate_tracks_node_lifecycle_and_survives_snapshot() {
        let mut c = Cluster::heterogeneous_priced(vec![
            (Resources::cpu_gpu(8.0, 4.0), 6.0),
            (Resources::cpu(8.0), 1.5),
        ]);
        assert!((c.price_rate() - 7.5).abs() < 1e-9);
        // Draining still bills; kill/retire stops the meter; restart
        // resumes it.
        c.begin_drain(1);
        assert!((c.price_rate() - 7.5).abs() < 1e-9);
        c.retire_node(1);
        assert!((c.price_rate() - 6.0).abs() < 1e-9);
        c.kill_node(0);
        assert!(c.price_rate().abs() < 1e-9);
        c.restart_node(0);
        assert!((c.price_rate() - 6.0).abs() < 1e-9);
        let id = c.add_node_priced(Resources::cpu(4.0), 2.0);
        assert_eq!(id, 1, "retired slot reused");
        assert!((c.price_rate() - 8.0).abs() < 1e-9);
        c.debug_check().unwrap();
        // Prices survive the snapshot round trip; pre-cost snapshots
        // (no "price" key) default to free, exercised via a stripped
        // legacy-style node object.
        let back = Cluster::restore_nodes(
            &crate::util::json::parse(&c.snapshot().to_string()).unwrap(),
        )
        .unwrap();
        assert!((back.node(0).price_per_hour - 6.0).abs() < 1e-9);
        assert!((back.price_rate() - 8.0).abs() < 1e-9);
        back.debug_check().unwrap();
        let legacy = r#"[{"total":{"cpu":4,"gpu":0},"alive":true,"draining":false,"retired":false}]"#;
        let old = Cluster::restore_nodes(&crate::util::json::parse(legacy).unwrap()).unwrap();
        assert_eq!(old.node(0).price_per_hour, 0.0);
        assert_eq!(old.price_rate(), 0.0);
    }

    #[test]
    fn node_utilization_counts_custom_dimensions() {
        // A node fully busy on a custom resource must not look idle to
        // the autoscaler just because cpu/gpu are mostly free.
        let mut c = Cluster::uniform(1, Resources::cpu(16.0).with_custom("tpu", 2.0));
        c.lease(0, Resources::cpu(2.0).with_custom("tpu", 2.0));
        assert!((c.node(0).utilization() - 1.0).abs() < 1e-9);
    }
}
