//! Nodes and the cluster they form.
//!
//! The substrate Tune runs on (the paper runs on Ray): a set of nodes
//! with resource capacities. Nodes can be added (autoscaling) or killed
//! (fault injection); killing a node surfaces the set of lease-holders
//! that were placed there so the coordinator can reschedule them.

use std::collections::BTreeMap;

use super::resources::Resources;

/// Index of a node within the cluster.
pub type NodeId = u32;
/// Handle for one granted resource lease.
pub type LeaseId = u64;

/// One machine: total capacity, what is still free, and who holds leases.
#[derive(Clone, Debug)]
pub struct Node {
    /// This node's id (its index in the cluster).
    pub id: NodeId,
    /// Full capacity.
    pub total: Resources,
    /// Capacity not currently leased.
    pub available: Resources,
    /// False once killed by fault injection (until restarted).
    pub alive: bool,
    /// Live leases placed on this node: lease -> demand.
    pub leases: BTreeMap<LeaseId, Resources>,
}

impl Node {
    /// A fresh, alive node with `total` capacity.
    pub fn new(id: NodeId, total: Resources) -> Self {
        Node { id, available: total.clone(), total, alive: true, leases: BTreeMap::new() }
    }

    /// Fraction of CPU capacity currently leased.
    pub fn utilization_cpu(&self) -> f64 {
        if self.total.cpu == 0.0 {
            0.0
        } else {
            1.0 - self.available.cpu / self.total.cpu
        }
    }
}

/// A set of nodes trials are placed onto.
#[derive(Clone, Debug)]
pub struct Cluster {
    /// All nodes, indexed by `NodeId`.
    pub nodes: Vec<Node>,
    next_lease: LeaseId,
}

impl Cluster {
    /// An empty cluster.
    pub fn new() -> Self {
        Cluster { nodes: Vec::new(), next_lease: 1 }
    }

    /// `n` identical nodes of `each` capacity.
    pub fn uniform(n: usize, each: Resources) -> Self {
        let mut c = Cluster::new();
        for _ in 0..n {
            c.add_node(each.clone());
        }
        c
    }

    /// Add a node with `total` capacity (autoscaling); returns its id.
    pub fn add_node(&mut self, total: Resources) -> NodeId {
        let id = self.nodes.len() as NodeId;
        self.nodes.push(Node::new(id, total));
        id
    }

    /// Borrow a node by id.
    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id as usize]
    }

    /// Grant a lease of `demand` on `node`. Caller must have verified
    /// the fit (the placement layer does); returns the lease id.
    pub fn lease(&mut self, node: NodeId, demand: Resources) -> LeaseId {
        let n = &mut self.nodes[node as usize];
        debug_assert!(n.alive && n.available.fits(&demand));
        n.available.acquire(&demand);
        let id = self.next_lease;
        self.next_lease += 1;
        n.leases.insert(id, demand);
        id
    }

    /// Release a lease; no-op if the node already died (its resources
    /// are gone with it).
    pub fn release(&mut self, node: NodeId, lease: LeaseId) {
        let n = &mut self.nodes[node as usize];
        if let Some(demand) = n.leases.remove(&lease) {
            if n.alive {
                n.available.release(&demand);
            }
        }
    }

    /// Kill a node; returns the lease ids that were running there.
    pub fn kill_node(&mut self, node: NodeId) -> Vec<LeaseId> {
        let n = &mut self.nodes[node as usize];
        n.alive = false;
        n.available = Resources::default();
        std::mem::take(&mut n.leases).into_keys().collect()
    }

    /// Restart a dead node with its original capacity.
    pub fn restart_node(&mut self, node: NodeId) {
        let n = &mut self.nodes[node as usize];
        if !n.alive {
            n.alive = true;
            n.available = n.total.clone();
        }
    }

    /// Iterator over nodes that are currently alive.
    pub fn alive_nodes(&self) -> impl Iterator<Item = &Node> {
        self.nodes.iter().filter(|n| n.alive)
    }

    /// Sum of free capacity across alive nodes.
    pub fn total_available(&self) -> Resources {
        let mut r = Resources::default();
        for n in self.alive_nodes() {
            r.release(&n.available);
        }
        r
    }

    /// Accounting invariant: per-node available + sum(leases) == total.
    pub fn check_invariants(&self) -> bool {
        self.nodes.iter().all(|n| {
            if !n.alive {
                return true;
            }
            let mut acc = n.available.clone();
            for d in n.leases.values() {
                acc.release(d);
            }
            (acc.cpu - n.total.cpu).abs() < 1e-6
                && (acc.gpu - n.total.gpu).abs() < 1e-6
                && n.available.is_valid()
        })
    }
}

impl Default for Cluster {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_and_release() {
        let mut c = Cluster::uniform(2, Resources::cpu_gpu(4.0, 1.0));
        let l = c.lease(0, Resources::cpu(2.0));
        assert_eq!(c.node(0).available.cpu, 2.0);
        assert!(c.check_invariants());
        c.release(0, l);
        assert_eq!(c.node(0).available.cpu, 4.0);
    }

    #[test]
    fn kill_node_returns_leases() {
        let mut c = Cluster::uniform(1, Resources::cpu(4.0));
        let l1 = c.lease(0, Resources::cpu(1.0));
        let l2 = c.lease(0, Resources::cpu(1.0));
        let mut killed = c.kill_node(0);
        killed.sort();
        assert_eq!(killed, vec![l1, l2]);
        assert!(!c.node(0).alive);
        // Release after death is a no-op, not a panic.
        c.release(0, l1);
        c.restart_node(0);
        assert_eq!(c.node(0).available.cpu, 4.0);
        assert!(c.check_invariants());
    }

    #[test]
    fn total_available_sums_alive_only() {
        let mut c = Cluster::uniform(3, Resources::cpu(2.0));
        c.kill_node(1);
        assert_eq!(c.total_available().cpu, 4.0);
    }
}
