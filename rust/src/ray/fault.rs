//! Fault injection for the substrate: per-step trial crashes and whole-
//! node failures, driven by the library's deterministic RNG so failure
//! scenarios replay exactly (C4 in DESIGN.md). The coordinator's
//! checkpoint-based recovery (§4.2 of the paper: "Tune ... relies on
//! checkpoints for fault tolerance") is exercised against this.

use crate::util::rng::Rng;

use super::cluster::NodeId;

/// What faults to inject, with what probability.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    /// Probability a single trial step raises (process crash).
    pub step_failure_prob: f64,
    /// Probability per executor tick that a random alive node dies.
    pub node_failure_prob: f64,
    /// Whether dead nodes come back after `node_restart_delay` ticks.
    pub nodes_restart: bool,
    pub node_restart_delay: u64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            step_failure_prob: 0.0,
            node_failure_prob: 0.0,
            nodes_restart: true,
            node_restart_delay: 50,
        }
    }
}

impl FaultPlan {
    /// No faults at all (the default).
    pub fn none() -> Self {
        Self::default()
    }

    /// Step crashes with probability `p`, no node failures.
    pub fn flaky_steps(p: f64) -> Self {
        FaultPlan { step_failure_prob: p, ..Default::default() }
    }

    /// Node failures with probability `p` per tick, no step crashes.
    pub fn flaky_nodes(p: f64) -> Self {
        FaultPlan { node_failure_prob: p, ..Default::default() }
    }

    /// True when this plan injects nothing.
    pub fn is_none(&self) -> bool {
        self.step_failure_prob == 0.0 && self.node_failure_prob == 0.0
    }
}

/// Deterministic fault source driven by the library RNG.
#[derive(Debug)]
pub struct FaultInjector {
    /// The plan being executed.
    pub plan: FaultPlan,
    rng: Rng,
    tick: u64,
    /// (node, tick at which to restart)
    pending_restarts: Vec<(NodeId, u64)>,
    /// Step crashes injected so far.
    pub injected_step_failures: u64,
    /// Node kills injected so far.
    pub injected_node_failures: u64,
}

impl FaultInjector {
    /// New injector for `plan`, seeded for exact replay.
    pub fn new(plan: FaultPlan, seed: u64) -> Self {
        FaultInjector {
            plan,
            rng: Rng::new(seed),
            tick: 0,
            pending_restarts: Vec::new(),
            injected_step_failures: 0,
            injected_node_failures: 0,
        }
    }

    /// Should this trial step crash?
    pub fn step_fails(&mut self) -> bool {
        if self.plan.step_failure_prob > 0.0 && self.rng.bool(self.plan.step_failure_prob) {
            self.injected_step_failures += 1;
            true
        } else {
            false
        }
    }

    /// Serialize mutable state for the experiment snapshot, so resumed
    /// runs draw the same fault stream they would have uninterrupted.
    pub fn snapshot(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("rng", crate::util::json::u64_to_json(self.rng.state())),
            ("tick", Json::Num(self.tick as f64)),
            (
                "pending_restarts",
                Json::Arr(
                    self.pending_restarts
                        .iter()
                        .map(|(n, t)| {
                            Json::Arr(vec![Json::Num(*n as f64), Json::Num(*t as f64)])
                        })
                        .collect(),
                ),
            ),
            ("step_failures", Json::Num(self.injected_step_failures as f64)),
            ("node_failures", Json::Num(self.injected_node_failures as f64)),
        ])
    }

    /// Rebuild state from a [`FaultInjector::snapshot`] value.
    pub fn restore(&mut self, snap: &crate::util::json::Json) -> Result<(), String> {
        let state = snap
            .get("rng")
            .and_then(crate::util::json::u64_from_json)
            .ok_or("fault snapshot: bad rng state")?;
        self.rng.set_state(state);
        self.tick = snap.get("tick").and_then(|v| v.as_u64()).ok_or("fault snapshot: bad tick")?;
        self.pending_restarts = snap
            .get("pending_restarts")
            .and_then(|p| p.as_arr())
            .ok_or("fault snapshot: bad restarts")?
            .iter()
            .map(|e| {
                let a = e.as_arr()?;
                Some((a.first()?.as_u64()? as NodeId, a.get(1)?.as_u64()?))
            })
            .collect::<Option<_>>()
            .ok_or("fault snapshot: bad restart entry")?;
        self.injected_step_failures =
            snap.get("step_failures").and_then(|v| v.as_u64()).unwrap_or(0);
        self.injected_node_failures =
            snap.get("node_failures").and_then(|v| v.as_u64()).unwrap_or(0);
        Ok(())
    }

    /// Advance one tick; returns (node to kill, nodes to restart now).
    pub fn tick(&mut self, alive: &[NodeId]) -> (Option<NodeId>, Vec<NodeId>) {
        self.tick += 1;
        // Fast path: most ticks have no queued restarts, and a fault-free
        // plan never will — don't churn two Vecs per event for that.
        let restarts: Vec<NodeId> = if self.pending_restarts.is_empty() {
            Vec::new()
        } else {
            let tick = self.tick;
            let (ready, keep): (Vec<_>, Vec<_>) =
                self.pending_restarts.drain(..).partition(|(_, t)| *t <= tick);
            self.pending_restarts = keep;
            ready.into_iter().map(|(n, _)| n).collect()
        };
        let kill = if self.plan.node_failure_prob > 0.0
            && !alive.is_empty()
            && self.rng.bool(self.plan.node_failure_prob)
        {
            let victim = *self.rng.choose(alive);
            self.injected_node_failures += 1;
            if self.plan.nodes_restart {
                self.pending_restarts
                    .push((victim, self.tick + self.plan.node_restart_delay));
            }
            Some(victim)
        } else {
            None
        };
        (kill, restarts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_faults_by_default() {
        let mut f = FaultInjector::new(FaultPlan::none(), 1);
        for _ in 0..1000 {
            assert!(!f.step_fails());
            let (kill, _) = f.tick(&[0, 1]);
            assert!(kill.is_none());
        }
    }

    #[test]
    fn step_failure_rate_tracks_prob() {
        let mut f = FaultInjector::new(FaultPlan::flaky_steps(0.2), 2);
        let fails = (0..10_000).filter(|_| f.step_fails()).count();
        assert!((fails as f64 / 10_000.0 - 0.2).abs() < 0.02, "{fails}");
    }

    #[test]
    fn node_failures_and_restarts() {
        let plan = FaultPlan { node_failure_prob: 0.5, node_restart_delay: 3, ..Default::default() };
        let mut f = FaultInjector::new(plan, 3);
        let mut killed = None;
        for _ in 0..20 {
            let (kill, _) = f.tick(&[0, 1, 2]);
            if kill.is_some() {
                killed = kill;
                break;
            }
        }
        let victim = killed.expect("should kill within 20 ticks at p=0.5");
        // Restart arrives within delay + slack ticks.
        let mut restarted = false;
        for _ in 0..10 {
            let (_, restarts) = f.tick(&[0, 1, 2]);
            if restarts.contains(&victim) {
                restarted = true;
                break;
            }
        }
        assert!(restarted);
    }

    #[test]
    fn deterministic_replay() {
        let mk = || FaultInjector::new(FaultPlan::flaky_steps(0.3), 42);
        let (mut a, mut b) = (mk(), mk());
        for _ in 0..100 {
            assert_eq!(a.step_fails(), b.step_fails());
        }
    }
}
