//! Resource vectors, the unit of admission control.
//!
//! Mirrors Ray's resource model as Tune uses it: each trial declares a
//! `{cpu, gpu, custom...}` demand; nodes hold capacities; the placement
//! layer does vector fits. Fractional quantities are allowed (Ray
//! permits e.g. 0.5 GPU).

use std::collections::BTreeMap;
use std::fmt;

const EPS: f64 = 1e-9;

/// A resource vector: CPU + GPU + named custom quantities, fractional
/// amounts allowed. Used both as node capacity and as trial demand.
#[derive(Clone, Debug, Default)]
pub struct Resources {
    /// CPU cores (fractional allowed).
    pub cpu: f64,
    /// GPU devices (fractional allowed, as in Ray).
    pub gpu: f64,
    /// Named custom resources (e.g. "tpu", "mem").
    pub custom: BTreeMap<String, f64>,
}

/// EPS-tolerant equality, matching the tolerance every fit/accounting
/// check in this module already uses. A raw-f64 derive would make a
/// vector that went through `acquire` + `release` compare unequal to its
/// original (floating-point round-trip error), while `fits` treats the
/// two as interchangeable. A custom key that one side omits compares
/// equal to an explicit 0.0 on the other, mirroring `fits`. Tolerant
/// comparisons are not transitive, so this is an accounting-equality
/// check, not a total equivalence — don't use `Resources` as a map key.
impl PartialEq for Resources {
    fn eq(&self, other: &Self) -> bool {
        let close = |a: f64, b: f64| (a - b).abs() < EPS;
        close(self.cpu, other.cpu)
            && close(self.gpu, other.gpu)
            && self
                .custom
                .keys()
                .chain(other.custom.keys())
                .all(|k| {
                    close(
                        self.custom.get(k).copied().unwrap_or(0.0),
                        other.custom.get(k).copied().unwrap_or(0.0),
                    )
                })
    }
}

impl Resources {
    /// CPU-only vector.
    pub fn cpu(cpu: f64) -> Self {
        Resources { cpu, ..Default::default() }
    }

    /// CPU + GPU vector.
    pub fn cpu_gpu(cpu: f64, gpu: f64) -> Self {
        Resources { cpu, gpu, ..Default::default() }
    }

    /// Builder-style custom resource entry.
    pub fn with_custom(mut self, key: &str, amount: f64) -> Self {
        self.custom.insert(key.to_string(), amount);
        self
    }

    /// Does `self` (a capacity) admit `demand`?
    pub fn fits(&self, demand: &Resources) -> bool {
        if self.cpu + EPS < demand.cpu || self.gpu + EPS < demand.gpu {
            return false;
        }
        demand
            .custom
            .iter()
            .all(|(k, v)| self.custom.get(k).copied().unwrap_or(0.0) + EPS >= *v)
    }

    /// Subtract a demand. Panics (debug) on underflow — the placement
    /// layer must have checked `fits` first; release/acquire imbalance is
    /// a coordinator bug, not a recoverable condition.
    pub fn acquire(&mut self, demand: &Resources) {
        debug_assert!(self.fits(demand), "acquire without fits: {self:?} < {demand:?}");
        self.cpu -= demand.cpu;
        self.gpu -= demand.gpu;
        for (k, v) in &demand.custom {
            *self.custom.entry(k.clone()).or_insert(0.0) -= v;
        }
    }

    /// Return a demand to this capacity (inverse of `acquire`).
    pub fn release(&mut self, demand: &Resources) {
        self.cpu += demand.cpu;
        self.gpu += demand.gpu;
        for (k, v) in &demand.custom {
            *self.custom.entry(k.clone()).or_insert(0.0) += v;
        }
    }

    /// All quantities zero (up to float tolerance).
    pub fn is_zero(&self) -> bool {
        self.cpu.abs() < EPS
            && self.gpu.abs() < EPS
            && self.custom.values().all(|v| v.abs() < EPS)
    }

    /// Non-negative up to float tolerance (accounting invariant).
    pub fn is_valid(&self) -> bool {
        self.cpu > -EPS && self.gpu > -EPS && self.custom.values().all(|v| *v > -EPS)
    }

    /// Validate a user-supplied *demand* vector: every quantity must be
    /// finite and non-negative. A NaN or negative demand would silently
    /// corrupt every downstream fit (`NaN` compares false both ways, so
    /// a NaN demand "fits" everywhere while wrecking the accounting).
    pub fn validate_demand(&self) -> Result<(), String> {
        let ok = |v: f64| v.is_finite() && v >= 0.0;
        if !ok(self.cpu) {
            return Err(format!("cpu demand {} must be finite and >= 0", self.cpu));
        }
        if !ok(self.gpu) {
            return Err(format!("gpu demand {} must be finite and >= 0", self.gpu));
        }
        for (k, v) in &self.custom {
            if !ok(*v) {
                return Err(format!("custom demand {k}={v} must be finite and >= 0"));
            }
        }
        Ok(())
    }

    /// This vector scaled by a non-negative factor (fair-share math:
    /// an experiment's resource share is `total * weight / total_weight`).
    pub fn scaled(&self, factor: f64) -> Resources {
        Resources {
            cpu: self.cpu * factor,
            gpu: self.gpu * factor,
            custom: self.custom.iter().map(|(k, v)| (k.clone(), v * factor)).collect(),
        }
    }

    /// Serialize as a flat `{cpu, gpu, <custom>...}` JSON map — the one
    /// encoding shared by cluster snapshots and experiment manifests.
    /// Custom keys cannot collide with the named fields: the spec
    /// parser routes "cpu"/"gpu" to the struct fields, never into
    /// `custom`.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::Obj(
            [
                ("cpu".to_string(), Json::Num(self.cpu)),
                ("gpu".to_string(), Json::Num(self.gpu)),
            ]
            .into_iter()
            .chain(self.custom.iter().map(|(k, v)| (k.clone(), Json::Num(*v))))
            .collect(),
        )
    }

    /// Rebuild from a [`Resources::to_json`] map (unknown keys are
    /// custom resources; absent `cpu`/`gpu` default to 0).
    pub fn from_json(j: &crate::util::json::Json) -> Option<Resources> {
        let obj = j.as_obj()?;
        let mut r = Resources::default();
        for (k, v) in obj {
            let amount = v.as_f64()?;
            match k.as_str() {
                "cpu" => r.cpu = amount,
                "gpu" => r.gpu = amount,
                _ => {
                    r.custom.insert(k.clone(), amount);
                }
            }
        }
        Some(r)
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{cpu:{:.2}, gpu:{:.2}", self.cpu, self.gpu)?;
        for (k, v) in &self.custom {
            write!(f, ", {k}:{v:.2}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_basic() {
        let cap = Resources::cpu_gpu(4.0, 1.0);
        assert!(cap.fits(&Resources::cpu_gpu(4.0, 1.0)));
        assert!(cap.fits(&Resources::cpu(0.5)));
        assert!(!cap.fits(&Resources::cpu_gpu(4.5, 0.0)));
        assert!(!cap.fits(&Resources::cpu_gpu(1.0, 2.0)));
    }

    #[test]
    fn fits_custom() {
        let cap = Resources::cpu(1.0).with_custom("tpu", 2.0);
        assert!(cap.fits(&Resources::cpu(1.0).with_custom("tpu", 2.0)));
        assert!(!cap.fits(&Resources::cpu(0.0).with_custom("tpu", 3.0)));
        assert!(!cap.fits(&Resources::cpu(0.0).with_custom("fpga", 1.0)));
    }

    #[test]
    fn acquire_release_roundtrip() {
        let mut cap = Resources::cpu_gpu(8.0, 2.0).with_custom("mem", 64.0);
        let d = Resources::cpu_gpu(3.0, 0.5).with_custom("mem", 16.0);
        cap.acquire(&d);
        assert!(cap.is_valid());
        assert_eq!(cap.cpu, 5.0);
        cap.release(&d);
        assert_eq!(cap, Resources::cpu_gpu(8.0, 2.0).with_custom("mem", 64.0));
    }

    #[test]
    fn equality_is_eps_tolerant() {
        // A release/acquire round trip may leave ~1e-16 of float dust;
        // the vectors must still compare equal.
        let a = Resources::cpu_gpu(0.3, 0.1);
        let mut b = Resources::cpu_gpu(0.1 + 0.2, 0.1);
        assert_eq!(a, b);
        // Absent custom key == explicit zero, mirroring `fits`.
        b.custom.insert("tpu".into(), 0.0);
        assert_eq!(a, b);
        b.custom.insert("tpu".into(), 1.0);
        assert_ne!(a, b);
        assert_ne!(a, Resources::cpu_gpu(0.3 + 1e-6, 0.1));
    }

    #[test]
    fn validate_demand_rejects_nan_and_negative() {
        assert!(Resources::cpu_gpu(1.0, 0.5).validate_demand().is_ok());
        assert!(Resources::cpu(f64::NAN).validate_demand().is_err());
        assert!(Resources::cpu_gpu(1.0, -0.5).validate_demand().is_err());
        assert!(Resources::cpu_gpu(1.0, f64::INFINITY).validate_demand().is_err());
        assert!(Resources::cpu(1.0).with_custom("tpu", f64::NAN).validate_demand().is_err());
        assert!(Resources::cpu(1.0).with_custom("tpu", -1.0).validate_demand().is_err());
        assert!(Resources::default().validate_demand().is_ok());
    }

    #[test]
    fn scaled_scales_every_dimension() {
        let r = Resources::cpu_gpu(8.0, 2.0).with_custom("tpu", 4.0).scaled(0.25);
        assert_eq!(r, Resources::cpu_gpu(2.0, 0.5).with_custom("tpu", 1.0));
    }

    #[test]
    fn json_roundtrip_preserves_every_dimension() {
        let r = Resources::cpu_gpu(0.5, 0.25).with_custom("tpu", 2.0);
        let text = r.to_json().to_string();
        let back = Resources::from_json(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, r);
        assert_eq!(
            Resources::from_json(&crate::util::json::parse("{}").unwrap()),
            Some(Resources::default())
        );
        assert!(Resources::from_json(&crate::util::json::parse("[1]").unwrap()).is_none());
    }

    #[test]
    fn fractional_gpu() {
        let mut cap = Resources::cpu_gpu(1.0, 1.0);
        let half = Resources::cpu_gpu(0.5, 0.5);
        cap.acquire(&half);
        assert!(cap.fits(&half));
        cap.acquire(&half);
        assert!(!cap.fits(&Resources::cpu_gpu(0.0, 0.1)));
        assert!(cap.is_valid());
    }
}
