//! Resource vectors, the unit of admission control.
//!
//! Mirrors Ray's resource model as Tune uses it: each trial declares a
//! `{cpu, gpu, custom...}` demand; nodes hold capacities; the placement
//! layer does vector fits. Fractional quantities are allowed (Ray
//! permits e.g. 0.5 GPU).

use std::collections::BTreeMap;
use std::fmt;

const EPS: f64 = 1e-9;

/// A resource vector: CPU + GPU + named custom quantities, fractional
/// amounts allowed. Used both as node capacity and as trial demand.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Resources {
    /// CPU cores (fractional allowed).
    pub cpu: f64,
    /// GPU devices (fractional allowed, as in Ray).
    pub gpu: f64,
    /// Named custom resources (e.g. "tpu", "mem").
    pub custom: BTreeMap<String, f64>,
}

impl Resources {
    /// CPU-only vector.
    pub fn cpu(cpu: f64) -> Self {
        Resources { cpu, ..Default::default() }
    }

    /// CPU + GPU vector.
    pub fn cpu_gpu(cpu: f64, gpu: f64) -> Self {
        Resources { cpu, gpu, ..Default::default() }
    }

    /// Builder-style custom resource entry.
    pub fn with_custom(mut self, key: &str, amount: f64) -> Self {
        self.custom.insert(key.to_string(), amount);
        self
    }

    /// Does `self` (a capacity) admit `demand`?
    pub fn fits(&self, demand: &Resources) -> bool {
        if self.cpu + EPS < demand.cpu || self.gpu + EPS < demand.gpu {
            return false;
        }
        demand
            .custom
            .iter()
            .all(|(k, v)| self.custom.get(k).copied().unwrap_or(0.0) + EPS >= *v)
    }

    /// Subtract a demand. Panics (debug) on underflow — the placement
    /// layer must have checked `fits` first; release/acquire imbalance is
    /// a coordinator bug, not a recoverable condition.
    pub fn acquire(&mut self, demand: &Resources) {
        debug_assert!(self.fits(demand), "acquire without fits: {self:?} < {demand:?}");
        self.cpu -= demand.cpu;
        self.gpu -= demand.gpu;
        for (k, v) in &demand.custom {
            *self.custom.entry(k.clone()).or_insert(0.0) -= v;
        }
    }

    /// Return a demand to this capacity (inverse of `acquire`).
    pub fn release(&mut self, demand: &Resources) {
        self.cpu += demand.cpu;
        self.gpu += demand.gpu;
        for (k, v) in &demand.custom {
            *self.custom.entry(k.clone()).or_insert(0.0) += v;
        }
    }

    /// All quantities zero (up to float tolerance).
    pub fn is_zero(&self) -> bool {
        self.cpu.abs() < EPS
            && self.gpu.abs() < EPS
            && self.custom.values().all(|v| v.abs() < EPS)
    }

    /// Non-negative up to float tolerance (accounting invariant).
    pub fn is_valid(&self) -> bool {
        self.cpu > -EPS && self.gpu > -EPS && self.custom.values().all(|v| *v > -EPS)
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{cpu:{:.2}, gpu:{:.2}", self.cpu, self.gpu)?;
        for (k, v) in &self.custom {
            write!(f, ", {k}:{v:.2}")?;
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_basic() {
        let cap = Resources::cpu_gpu(4.0, 1.0);
        assert!(cap.fits(&Resources::cpu_gpu(4.0, 1.0)));
        assert!(cap.fits(&Resources::cpu(0.5)));
        assert!(!cap.fits(&Resources::cpu_gpu(4.5, 0.0)));
        assert!(!cap.fits(&Resources::cpu_gpu(1.0, 2.0)));
    }

    #[test]
    fn fits_custom() {
        let cap = Resources::cpu(1.0).with_custom("tpu", 2.0);
        assert!(cap.fits(&Resources::cpu(1.0).with_custom("tpu", 2.0)));
        assert!(!cap.fits(&Resources::cpu(0.0).with_custom("tpu", 3.0)));
        assert!(!cap.fits(&Resources::cpu(0.0).with_custom("fpga", 1.0)));
    }

    #[test]
    fn acquire_release_roundtrip() {
        let mut cap = Resources::cpu_gpu(8.0, 2.0).with_custom("mem", 64.0);
        let d = Resources::cpu_gpu(3.0, 0.5).with_custom("mem", 16.0);
        cap.acquire(&d);
        assert!(cap.is_valid());
        assert_eq!(cap.cpu, 5.0);
        cap.release(&d);
        assert_eq!(cap, Resources::cpu_gpu(8.0, 2.0).with_custom("mem", 64.0));
    }

    #[test]
    fn fractional_gpu() {
        let mut cap = Resources::cpu_gpu(1.0, 1.0);
        let half = Resources::cpu_gpu(0.5, 0.5);
        cap.acquire(&half);
        assert!(cap.fits(&half));
        cap.acquire(&half);
        assert!(!cap.fits(&Resources::cpu_gpu(0.0, 0.1)));
        assert!(cap.is_valid());
    }
}
