//! Ray-style object store (§4.3.2 of the paper): `put` an immutable blob
//! once, `get` it from any node; the store tracks which nodes hold a
//! copy and accounts inter-node transfer bytes, so the e2e example can
//! demonstrate weight/dataset broadcast (`ray.put` / `ray.get`) and the
//! benches can report transfer volume.
//!
//! Objects are `Arc<[u8]>` — the same currency as `CheckpointStore` —
//! so checkpoint blobs hand off between the two layers as refcount
//! bumps, never byte copies. Optionally the store shares the
//! checkpoint layer's content-addressed [`ChunkTable`], in which case
//! every `put` also interns the payload's chunks: broadcast accounting
//! then sees *deduped* bytes (`unique_bytes`), and a blob that already
//! lives in the checkpoint store costs no additional chunk storage.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::checkpoint::chunk::{intern_manifest, ContentHash, SharedChunkTable};
use crate::checkpoint::ChunkTable;

use super::cluster::NodeId;

/// Handle to one immutable stored object.
pub type ObjectId = u64;

/// In-process Ray-style object store with transfer accounting.
#[derive(Debug, Default)]
pub struct ObjectStore {
    next_id: ObjectId,
    objects: BTreeMap<ObjectId, Arc<[u8]>>,
    /// Chunk manifests per object, when a chunk table is attached.
    manifests: BTreeMap<ObjectId, Vec<(ContentHash, u32)>>,
    /// Shared content-addressed chunk tier (usually the checkpoint
    /// store's table).
    chunks: Option<SharedChunkTable>,
    /// Which nodes hold a local copy of each object.
    locations: BTreeMap<ObjectId, BTreeSet<NodeId>>,
    /// Inter-node transfers performed.
    pub transfers: u64,
    /// Bytes moved across nodes.
    pub transfer_bytes: u64,
    /// Reads served from a local copy.
    pub local_hits: u64,
}

impl ObjectStore {
    /// An empty store.
    pub fn new() -> Self {
        Self { next_id: 1, ..Default::default() }
    }

    /// Account object payloads in a shared content-addressed chunk
    /// table (see module docs). Attach before the first `put`.
    pub fn with_chunks(mut self, table: SharedChunkTable) -> Self {
        debug_assert!(self.objects.is_empty(), "attach the chunk table before puts");
        self.chunks = Some(table);
        self
    }

    /// Store `data`, creating the primary copy on `node`. Accepts a
    /// `Vec<u8>` or an already-shared `Arc<[u8]>` (e.g. straight out of
    /// `CheckpointStore::get`) — the latter stores without copying.
    pub fn put(&mut self, node: NodeId, data: impl Into<Arc<[u8]>>) -> ObjectId {
        let data: Arc<[u8]> = data.into();
        let id = self.next_id;
        self.next_id += 1;
        if let Some(table) = &self.chunks {
            let mut table = table.lock().expect("chunk table lock");
            let manifest = intern_manifest(&mut table, &data);
            self.manifests.insert(id, manifest);
        }
        self.objects.insert(id, data);
        self.locations.entry(id).or_default().insert(node);
        id
    }

    /// Fetch an object from `node`. First access from a node without a
    /// local copy counts as one inter-node transfer (and caches it
    /// there); later accesses are local hits. The returned handle is a
    /// refcount bump on the stored allocation.
    pub fn get(&mut self, node: NodeId, id: ObjectId) -> Option<Arc<[u8]>> {
        let data = Arc::clone(self.objects.get(&id)?);
        let locs = self.locations.get_mut(&id).expect("locations tracked per object");
        if locs.contains(&node) {
            self.local_hits += 1;
        } else {
            self.transfers += 1;
            self.transfer_bytes += data.len() as u64;
            locs.insert(node);
        }
        Some(data)
    }

    /// Is the object still stored?
    pub fn contains(&self, id: ObjectId) -> bool {
        self.objects.contains_key(&id)
    }

    /// Drop an object everywhere (checkpoint GC). With a chunk table
    /// attached, releases the object's chunk references too.
    pub fn delete(&mut self, id: ObjectId) {
        self.objects.remove(&id);
        self.locations.remove(&id);
        if let Some(manifest) = self.manifests.remove(&id) {
            if let Some(table) = &self.chunks {
                let mut table = table.lock().expect("chunk table lock");
                for (key, _) in manifest {
                    table.release(key);
                }
            }
        }
    }

    /// A node died: its cached copies are gone (primary copies live in
    /// the driver's memory in our in-process model, so objects stay
    /// fetchable — matching Tune's "metadata in memory, checkpoints for
    /// fault tolerance" design).
    pub fn evict_node(&mut self, node: NodeId) {
        for locs in self.locations.values_mut() {
            locs.remove(&node);
        }
    }

    /// Number of stored objects.
    pub fn len(&self) -> usize {
        self.objects.len()
    }
    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }
    /// Total *logical* payload bytes currently stored (pre-dedup).
    pub fn total_bytes(&self) -> u64 {
        self.objects.values().map(|o| o.len() as u64).sum()
    }

    /// Deduped bytes this store's objects occupy in the chunk table:
    /// each distinct chunk referenced by a live manifest counts once,
    /// even when several objects (or the checkpoint store) share it.
    /// Falls back to [`ObjectStore::total_bytes`] without a table.
    pub fn unique_bytes(&self) -> u64 {
        if self.chunks.is_none() {
            return self.total_bytes();
        }
        let mut seen: BTreeMap<ContentHash, u64> = BTreeMap::new();
        for manifest in self.manifests.values() {
            for (key, len) in manifest {
                seen.insert(*key, u64::from(*len));
            }
        }
        seen.values().sum()
    }

    /// Expected chunk refcount contribution of this store's live
    /// objects, for cross-layer `ChunkTable::debug_check` runs.
    #[doc(hidden)]
    pub fn debug_chunk_refs(&self) -> BTreeMap<ContentHash, u64> {
        let mut refs: BTreeMap<ContentHash, u64> = BTreeMap::new();
        for manifest in self.manifests.values() {
            for (key, _) in manifest {
                *refs.entry(*key).or_default() += 1;
            }
        }
        refs
    }

    /// The attached chunk table, if any.
    pub fn chunk_table(&self) -> Option<&SharedChunkTable> {
        self.chunks.as_ref()
    }
}

/// Convenience: a fresh table handle for wiring a store pair together
/// in tests/examples without importing the checkpoint module.
pub fn shared_chunk_table() -> SharedChunkTable {
    Arc::new(std::sync::Mutex::new(ChunkTable::default()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut s = ObjectStore::new();
        let id = s.put(0, vec![1, 2, 3]);
        assert_eq!(&s.get(0, id).unwrap()[..], &[1, 2, 3]);
        assert_eq!(s.local_hits, 1);
        assert_eq!(s.transfers, 0);
    }

    #[test]
    fn get_is_a_refcount_bump_not_a_copy() {
        let mut s = ObjectStore::new();
        let blob: Arc<[u8]> = vec![9u8; 4096].into();
        let id = s.put(0, Arc::clone(&blob));
        let got = s.get(0, id).unwrap();
        assert!(Arc::ptr_eq(&blob, &got), "same allocation end to end");
    }

    #[test]
    fn remote_get_transfers_once() {
        let mut s = ObjectStore::new();
        let id = s.put(0, vec![0u8; 100]);
        s.get(1, id).unwrap();
        s.get(1, id).unwrap();
        assert_eq!(s.transfers, 1);
        assert_eq!(s.transfer_bytes, 100);
        assert_eq!(s.local_hits, 1);
    }

    #[test]
    fn broadcast_accounting() {
        let mut s = ObjectStore::new();
        let id = s.put(0, vec![0u8; 1000]);
        for node in 1..=4 {
            s.get(node, id).unwrap();
        }
        assert_eq!(s.transfers, 4);
        assert_eq!(s.transfer_bytes, 4000);
    }

    #[test]
    fn evict_node_forces_retransfer() {
        let mut s = ObjectStore::new();
        let id = s.put(0, vec![0u8; 10]);
        s.get(1, id).unwrap();
        s.evict_node(1);
        s.get(1, id).unwrap();
        assert_eq!(s.transfers, 2);
    }

    #[test]
    fn missing_object_is_none() {
        let mut s = ObjectStore::new();
        assert!(s.get(0, 99).is_none());
        let id = s.put(0, vec![1]);
        s.delete(id);
        assert!(s.get(0, id).is_none());
    }

    #[test]
    fn shared_chunk_table_dedups_broadcast_payloads() {
        let table = shared_chunk_table();
        let mut s = ObjectStore::new().with_chunks(Arc::clone(&table));
        let payload = vec![3u8; 20_000];
        let a = s.put(0, payload.clone());
        let b = s.put(1, payload.clone());
        assert_eq!(s.total_bytes(), 40_000, "logical bytes double-count");
        assert_eq!(s.unique_bytes(), 20_000, "chunk tier stores the payload once");
        assert_eq!(table.lock().unwrap().physical_bytes(), 20_000);
        table.lock().unwrap().debug_check(&s.debug_chunk_refs(), true, false);
        // Deleting one referent keeps the chunks; deleting both frees.
        s.delete(a);
        assert_eq!(table.lock().unwrap().physical_bytes(), 20_000);
        s.delete(b);
        assert_eq!(table.lock().unwrap().physical_bytes(), 0);
    }

    #[test]
    fn checkpoint_blob_handoff_costs_no_new_chunk_bytes() {
        use crate::checkpoint::CheckpointStore;
        let table = shared_chunk_table();
        let mut ckpts = CheckpointStore::new().with_chunk_table(Arc::clone(&table));
        let mut objs = ObjectStore::new().with_chunks(Arc::clone(&table));
        let cid = ckpts.save(1, 1, vec![8u8; 25_000]);
        let before = table.lock().unwrap().physical_bytes();
        // Broadcast the checkpoint through the object store (PBT
        // exploit handing weights to a remote node).
        let blob = ckpts.get(cid).unwrap();
        let oid = objs.put(0, blob);
        assert_eq!(table.lock().unwrap().physical_bytes(), before);
        assert_eq!(&objs.get(3, oid).unwrap()[..], &[8u8; 25_000][..]);
        ckpts.debug_check_store();
    }
}
