//! Ray-style object store (§4.3.2 of the paper): `put` an immutable blob
//! once, `get` it from any node; the store tracks which nodes hold a
//! copy and accounts inter-node transfer bytes, so the e2e example can
//! demonstrate weight/dataset broadcast (`ray.put` / `ray.get`) and the
//! benches can report transfer volume.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use super::cluster::NodeId;

/// Handle to one immutable stored object.
pub type ObjectId = u64;

/// In-process Ray-style object store with transfer accounting.
#[derive(Debug, Default)]
pub struct ObjectStore {
    next_id: ObjectId,
    objects: BTreeMap<ObjectId, Arc<Vec<u8>>>,
    /// Which nodes hold a local copy of each object.
    locations: BTreeMap<ObjectId, BTreeSet<NodeId>>,
    /// Inter-node transfers performed.
    pub transfers: u64,
    /// Bytes moved across nodes.
    pub transfer_bytes: u64,
    /// Reads served from a local copy.
    pub local_hits: u64,
}

impl ObjectStore {
    /// An empty store.
    pub fn new() -> Self {
        Self { next_id: 1, ..Default::default() }
    }

    /// Store `data`, creating the primary copy on `node`.
    pub fn put(&mut self, node: NodeId, data: Vec<u8>) -> ObjectId {
        let id = self.next_id;
        self.next_id += 1;
        self.objects.insert(id, Arc::new(data));
        self.locations.entry(id).or_default().insert(node);
        id
    }

    /// Fetch an object from `node`. First access from a node without a
    /// local copy counts as one inter-node transfer (and caches it
    /// there); later accesses are local hits.
    pub fn get(&mut self, node: NodeId, id: ObjectId) -> Option<Arc<Vec<u8>>> {
        let data = self.objects.get(&id)?.clone();
        let locs = self.locations.get_mut(&id).expect("locations tracked per object");
        if locs.contains(&node) {
            self.local_hits += 1;
        } else {
            self.transfers += 1;
            self.transfer_bytes += data.len() as u64;
            locs.insert(node);
        }
        Some(data)
    }

    /// Is the object still stored?
    pub fn contains(&self, id: ObjectId) -> bool {
        self.objects.contains_key(&id)
    }

    /// Drop an object everywhere (checkpoint GC).
    pub fn delete(&mut self, id: ObjectId) {
        self.objects.remove(&id);
        self.locations.remove(&id);
    }

    /// A node died: its cached copies are gone (primary copies live in
    /// the driver's memory in our in-process model, so objects stay
    /// fetchable — matching Tune's "metadata in memory, checkpoints for
    /// fault tolerance" design).
    pub fn evict_node(&mut self, node: NodeId) {
        for locs in self.locations.values_mut() {
            locs.remove(&node);
        }
    }

    /// Number of stored objects.
    pub fn len(&self) -> usize {
        self.objects.len()
    }
    /// True when nothing is stored.
    pub fn is_empty(&self) -> bool {
        self.objects.is_empty()
    }
    /// Total payload bytes currently stored.
    pub fn total_bytes(&self) -> u64 {
        self.objects.values().map(|o| o.len() as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let mut s = ObjectStore::new();
        let id = s.put(0, vec![1, 2, 3]);
        assert_eq!(&*s.get(0, id).unwrap(), &vec![1, 2, 3]);
        assert_eq!(s.local_hits, 1);
        assert_eq!(s.transfers, 0);
    }

    #[test]
    fn remote_get_transfers_once() {
        let mut s = ObjectStore::new();
        let id = s.put(0, vec![0u8; 100]);
        s.get(1, id).unwrap();
        s.get(1, id).unwrap();
        assert_eq!(s.transfers, 1);
        assert_eq!(s.transfer_bytes, 100);
        assert_eq!(s.local_hits, 1);
    }

    #[test]
    fn broadcast_accounting() {
        let mut s = ObjectStore::new();
        let id = s.put(0, vec![0u8; 1000]);
        for node in 1..=4 {
            s.get(node, id).unwrap();
        }
        assert_eq!(s.transfers, 4);
        assert_eq!(s.transfer_bytes, 4000);
    }

    #[test]
    fn evict_node_forces_retransfer() {
        let mut s = ObjectStore::new();
        let id = s.put(0, vec![0u8; 10]);
        s.get(1, id).unwrap();
        s.evict_node(1);
        s.get(1, id).unwrap();
        assert_eq!(s.transfers, 2);
    }

    #[test]
    fn missing_object_is_none() {
        let mut s = ObjectStore::new();
        assert!(s.get(0, 99).is_none());
        let id = s.put(0, vec![1]);
        s.delete(id);
        assert!(s.get(0, id).is_none());
    }
}
