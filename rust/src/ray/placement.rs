//! Two-level placement, the Ray property the paper singles out (§5):
//! "task scheduling decisions are typically made on the local machine
//! when possible, only 'spilling over' to other machines when local
//! resources are exhausted. This avoids any central bottleneck."
//!
//! Each placement request carries an *origin* node (the node the
//! requesting driver/actor lives on; trial drivers originate on the head
//! node, nested child tasks originate on their trial's node). The local
//! node is tried first in O(1); only on local exhaustion do we scan for
//! spill-over — and that scan starts from a rotating cursor so the spill
//! path is also O(#nodes-scanned), not O(#nodes * #pending).

use super::cluster::{Cluster, LeaseId, NodeId};
use super::resources::Resources;

/// Placement outcome counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlacementStats {
    /// Requests satisfied on the origin node.
    pub local: u64,
    /// Requests spilled to another node.
    pub spilled: u64,
    /// Requests that found no capacity anywhere.
    pub failed: u64,
}

impl PlacementStats {
    /// All placement attempts.
    pub fn total(&self) -> u64 {
        self.local + self.spilled + self.failed
    }
    /// Fraction of successful placements that spilled.
    pub fn spill_fraction(&self) -> f64 {
        let placed = self.local + self.spilled;
        if placed == 0 {
            0.0
        } else {
            self.spilled as f64 / placed as f64
        }
    }
}

/// A successful placement: where, under which lease, and how.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Node the demand landed on.
    pub node: NodeId,
    /// Lease granted by the cluster.
    pub lease: LeaseId,
    /// True when the origin node was exhausted and the demand spilled.
    pub spilled: bool,
}

/// Local-first, spill-over placement (the paper's §5 property).
#[derive(Clone, Debug, Default)]
pub struct TwoLevelScheduler {
    cursor: usize,
    /// Outcome counters (read by benches and result summaries).
    pub stats: PlacementStats,
    /// Fail-fast memo: the last demand that failed a full spill scan,
    /// with the cluster's grow epoch at that moment. While the epoch is
    /// unchanged no placeable capacity can have appeared, so repeating
    /// the identical request fails in O(1) instead of rescanning every
    /// node — the saturated-cluster steady state, where the runner
    /// probes placement once per completion event.
    fail_cache: Option<(Resources, u64)>,
}

impl TwoLevelScheduler {
    /// A fresh scheduler with zeroed stats.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop the fail-fast memo. Required whenever the cluster instance
    /// behind previous calls is replaced (experiment restore), because
    /// grow epochs are only comparable within one cluster's lifetime.
    pub fn invalidate(&mut self) {
        self.fail_cache = None;
    }

    /// Place `demand` preferring `origin`; spill over otherwise.
    pub fn place(
        &mut self,
        cluster: &mut Cluster,
        origin: NodeId,
        demand: &Resources,
    ) -> Option<Placement> {
        // An empty node table (a cluster drained to nothing) can satisfy
        // no demand; without this guard the level-1 origin lookup indexes
        // past the table.
        if cluster.nodes.is_empty() {
            self.stats.failed += 1;
            return None;
        }
        if let Some((d, epoch)) = &self.fail_cache {
            if *epoch == cluster.grow_epoch() && d == demand {
                self.stats.failed += 1;
                return None;
            }
        }
        // Level 1: local decision. Draining nodes are never placement
        // targets — the autoscaler is emptying them.
        {
            let n = cluster.node(origin);
            if n.alive && !n.draining && n.available.fits(demand) {
                let lease = cluster.lease(origin, demand.clone());
                self.stats.local += 1;
                return Some(Placement { node: origin, lease, spilled: false });
            }
        }
        // Level 2: spill over, rotating start to spread load.
        let n_nodes = cluster.nodes.len();
        for k in 0..n_nodes {
            let id = ((self.cursor + k) % n_nodes) as NodeId;
            if id == origin {
                continue;
            }
            let n = cluster.node(id);
            if n.alive && !n.draining && n.available.fits(demand) {
                self.cursor = (self.cursor + k + 1) % n_nodes;
                let lease = cluster.lease(id, demand.clone());
                self.stats.spilled += 1;
                return Some(Placement { node: id, lease, spilled: true });
            }
        }
        self.fail_cache = Some((demand.clone(), cluster.grow_epoch()));
        self.stats.failed += 1;
        None
    }

    /// Centralized baseline (for the C3 scaling ablation): always scans
    /// every node from zero and picks the least-loaded fit — the
    /// "central bottleneck" policy the paper contrasts with. The origin
    /// still decides local-vs-spilled accounting, so `spill_fraction()`
    /// stays comparable with the two-level policy instead of pinning at
    /// 100%.
    pub fn place_centralized(
        &mut self,
        cluster: &mut Cluster,
        origin: NodeId,
        demand: &Resources,
    ) -> Option<Placement> {
        let mut best: Option<(NodeId, f64)> = None;
        for n in cluster.nodes.iter() {
            if n.alive && !n.draining && n.available.fits(demand) {
                let load = n.utilization_cpu();
                if best.map_or(true, |(_, b)| load < b) {
                    best = Some((n.id, load));
                }
            }
        }
        match best {
            Some((id, _)) => {
                let lease = cluster.lease(id, demand.clone());
                let spilled = id != origin;
                if spilled {
                    self.stats.spilled += 1;
                } else {
                    self.stats.local += 1;
                }
                Some(Placement { node: id, lease, spilled })
            }
            None => {
                self.stats.failed += 1;
                None
            }
        }
    }

    /// Throughput-aware placement: scan every live, non-draining node
    /// that fits `demand` and take the one with the highest `score`
    /// (predicted steps/sec ÷ opportunity cost of the slot; ties break
    /// to the lowest node id so the choice is deterministic). Shares the
    /// fail-fast memo and the local/spilled accounting with [`place`];
    /// callers flip to it only once throughput profiles are warm.
    pub fn place_ranked<F: Fn(&super::cluster::Node) -> f64>(
        &mut self,
        cluster: &mut Cluster,
        origin: NodeId,
        demand: &Resources,
        score: F,
    ) -> Option<Placement> {
        if let Some((d, epoch)) = &self.fail_cache {
            if *epoch == cluster.grow_epoch() && d == demand {
                self.stats.failed += 1;
                return None;
            }
        }
        let mut best: Option<(NodeId, f64)> = None;
        for n in cluster.nodes.iter() {
            if n.alive && !n.draining && n.available.fits(demand) {
                let s = score(n);
                // Strictly-greater keeps the first (lowest-id) node on
                // ties; `asc` gives a total order even if a score is NaN.
                if best.map_or(true, |(_, b)| {
                    crate::util::order::asc(s, b) == std::cmp::Ordering::Greater
                }) {
                    best = Some((n.id, s));
                }
            }
        }
        match best {
            Some((id, _)) => {
                let lease = cluster.lease(id, demand.clone());
                let spilled = id != origin;
                if spilled {
                    self.stats.spilled += 1;
                } else {
                    self.stats.local += 1;
                }
                Some(Placement { node: id, lease, spilled })
            }
            None => {
                self.fail_cache = Some((demand.clone(), cluster.grow_epoch()));
                self.stats.failed += 1;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefers_local() {
        let mut c = Cluster::uniform(3, Resources::cpu(2.0));
        let mut s = TwoLevelScheduler::new();
        let p = s.place(&mut c, 1, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
        assert!(!p.spilled);
        assert_eq!(s.stats.local, 1);
    }

    #[test]
    fn spills_on_local_exhaustion() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        let mut s = TwoLevelScheduler::new();
        let _ = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        let p = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
        assert!(p.spilled);
        assert_eq!(s.stats.spill_fraction(), 0.5);
    }

    #[test]
    fn fails_when_full() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        let mut s = TwoLevelScheduler::new();
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_some());
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_some());
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
        assert_eq!(s.stats.failed, 1);
    }

    #[test]
    fn fail_cache_clears_when_capacity_frees() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        let mut s = TwoLevelScheduler::new();
        let p = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert!(s.place(&mut c, 1, &Resources::cpu(1.0)).is_some());
        // Saturated: the first miss scans, repeats hit the memo — both
        // still count as failures.
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
        assert_eq!(s.stats.failed, 2);
        // A release bumps the grow epoch, so placement works again.
        c.release(p.node, p.lease);
        let q = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(q.node, 0);
        // A different demand never hits the memo.
        assert!(s.place(&mut c, 0, &Resources::cpu(0.5)).is_none());
        assert!(s.place(&mut c, 0, &Resources::cpu(0.25)).is_none());
        assert_eq!(s.stats.failed, 4);
    }

    #[test]
    fn skips_dead_nodes() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        c.kill_node(0);
        let mut s = TwoLevelScheduler::new();
        let p = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
    }

    #[test]
    fn skips_draining_nodes() {
        let mut c = Cluster::uniform(2, Resources::cpu(2.0));
        c.begin_drain(0);
        let mut s = TwoLevelScheduler::new();
        let p = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
        // Node 1 still has free capacity, but draining blocks it too.
        c.begin_drain(1);
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
    }

    #[test]
    fn centralized_picks_least_loaded() {
        let mut c = Cluster::uniform(2, Resources::cpu(4.0));
        let mut s = TwoLevelScheduler::new();
        c.lease(0, Resources::cpu(3.0));
        let p = s.place_centralized(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
    }

    #[test]
    fn centralized_counts_origin_hits_as_local() {
        // The satellite bug: landing on the origin used to count as a
        // spill, so the centralized baseline always read 100% spill.
        let mut c = Cluster::uniform(2, Resources::cpu(4.0));
        let mut s = TwoLevelScheduler::new();
        // Node 1 busier than node 0 → least-loaded pick IS the origin.
        c.lease(1, Resources::cpu(3.0));
        let p = s.place_centralized(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 0);
        assert!(!p.spilled);
        assert_eq!((s.stats.local, s.stats.spilled), (1, 0));
        // Now node 0 is strictly busier → a genuine spill to node 1.
        c.lease(0, Resources::cpu(2.5));
        let q = s.place_centralized(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(q.node, 1);
        assert!(q.spilled);
        assert_eq!((s.stats.local, s.stats.spilled), (1, 1));
        assert_eq!(s.stats.spill_fraction(), 0.5);
    }

    #[test]
    fn empty_cluster_fails_cleanly() {
        // A node table drained to nothing must fail the request, not
        // index past the table in the level-1 origin lookup.
        let mut c = Cluster::uniform(0, Resources::cpu(1.0));
        let mut s = TwoLevelScheduler::new();
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
        assert!(s.place_centralized(&mut c, 0, &Resources::cpu(1.0)).is_none());
        assert!(s.place_ranked(&mut c, 0, &Resources::cpu(1.0), |_| 1.0).is_none());
        assert_eq!(s.stats.failed, 3);
        assert_eq!(s.stats.total(), 3);
    }

    #[test]
    fn ranked_takes_best_score_and_breaks_ties_low() {
        let mut c = Cluster::uniform(3, Resources::cpu(2.0));
        let mut s = TwoLevelScheduler::new();
        // Highest score wins regardless of origin or id order.
        let p = s
            .place_ranked(&mut c, 0, &Resources::cpu(1.0), |n| n.id as f64)
            .unwrap();
        assert_eq!(p.node, 2);
        assert!(p.spilled);
        // Equal scores tie-break to the lowest id — node 0, the origin,
        // which counts as a local hit.
        let q = s.place_ranked(&mut c, 0, &Resources::cpu(1.0), |_| 7.0).unwrap();
        assert_eq!(q.node, 0);
        assert!(!q.spilled);
        assert_eq!((s.stats.local, s.stats.spilled), (1, 1));
    }

    #[test]
    fn ranked_skips_unfit_and_uses_fail_cache() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        let mut s = TwoLevelScheduler::new();
        c.begin_drain(1);
        // Draining node 1 is excluded even though its score is higher.
        let p = s
            .place_ranked(&mut c, 0, &Resources::cpu(1.0), |n| n.id as f64)
            .unwrap();
        assert_eq!(p.node, 0);
        // Saturated: the miss populates the memo, the repeat hits it.
        assert!(s.place_ranked(&mut c, 0, &Resources::cpu(1.0), |_| 1.0).is_none());
        assert!(s.place_ranked(&mut c, 0, &Resources::cpu(1.0), |_| 1.0).is_none());
        assert_eq!(s.stats.failed, 2);
        // Freed capacity bumps the grow epoch and clears the memo.
        c.release(p.node, p.lease);
        assert!(s.place_ranked(&mut c, 0, &Resources::cpu(1.0), |_| 1.0).is_some());
    }
}
