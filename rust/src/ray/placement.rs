//! Two-level placement, the Ray property the paper singles out (§5):
//! "task scheduling decisions are typically made on the local machine
//! when possible, only 'spilling over' to other machines when local
//! resources are exhausted. This avoids any central bottleneck."
//!
//! Each placement request carries an *origin* node (the node the
//! requesting driver/actor lives on; trial drivers originate on the head
//! node, nested child tasks originate on their trial's node). The local
//! node is tried first in O(1); only on local exhaustion do we scan for
//! spill-over — and that scan starts from a rotating cursor so the spill
//! path is also O(#nodes-scanned), not O(#nodes * #pending).

use super::cluster::{Cluster, LeaseId, NodeId};
use super::resources::Resources;

/// Placement outcome counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PlacementStats {
    /// Requests satisfied on the origin node.
    pub local: u64,
    /// Requests spilled to another node.
    pub spilled: u64,
    /// Requests that found no capacity anywhere.
    pub failed: u64,
}

impl PlacementStats {
    /// All placement attempts.
    pub fn total(&self) -> u64 {
        self.local + self.spilled + self.failed
    }
    /// Fraction of successful placements that spilled.
    pub fn spill_fraction(&self) -> f64 {
        let placed = self.local + self.spilled;
        if placed == 0 {
            0.0
        } else {
            self.spilled as f64 / placed as f64
        }
    }
}

/// A successful placement: where, under which lease, and how.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Node the demand landed on.
    pub node: NodeId,
    /// Lease granted by the cluster.
    pub lease: LeaseId,
    /// True when the origin node was exhausted and the demand spilled.
    pub spilled: bool,
}

/// Local-first, spill-over placement (the paper's §5 property).
#[derive(Clone, Debug, Default)]
pub struct TwoLevelScheduler {
    cursor: usize,
    /// Outcome counters (read by benches and result summaries).
    pub stats: PlacementStats,
    /// Fail-fast memo: the last demand that failed a full spill scan,
    /// with the cluster's grow epoch at that moment. While the epoch is
    /// unchanged no placeable capacity can have appeared, so repeating
    /// the identical request fails in O(1) instead of rescanning every
    /// node — the saturated-cluster steady state, where the runner
    /// probes placement once per completion event.
    fail_cache: Option<(Resources, u64)>,
}

impl TwoLevelScheduler {
    /// A fresh scheduler with zeroed stats.
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop the fail-fast memo. Required whenever the cluster instance
    /// behind previous calls is replaced (experiment restore), because
    /// grow epochs are only comparable within one cluster's lifetime.
    pub fn invalidate(&mut self) {
        self.fail_cache = None;
    }

    /// Place `demand` preferring `origin`; spill over otherwise.
    pub fn place(
        &mut self,
        cluster: &mut Cluster,
        origin: NodeId,
        demand: &Resources,
    ) -> Option<Placement> {
        if let Some((d, epoch)) = &self.fail_cache {
            if *epoch == cluster.grow_epoch() && d == demand {
                self.stats.failed += 1;
                return None;
            }
        }
        // Level 1: local decision. Draining nodes are never placement
        // targets — the autoscaler is emptying them.
        {
            let n = cluster.node(origin);
            if n.alive && !n.draining && n.available.fits(demand) {
                let lease = cluster.lease(origin, demand.clone());
                self.stats.local += 1;
                return Some(Placement { node: origin, lease, spilled: false });
            }
        }
        // Level 2: spill over, rotating start to spread load.
        let n_nodes = cluster.nodes.len();
        for k in 0..n_nodes {
            let id = ((self.cursor + k) % n_nodes) as NodeId;
            if id == origin {
                continue;
            }
            let n = cluster.node(id);
            if n.alive && !n.draining && n.available.fits(demand) {
                self.cursor = (self.cursor + k + 1) % n_nodes;
                let lease = cluster.lease(id, demand.clone());
                self.stats.spilled += 1;
                return Some(Placement { node: id, lease, spilled: true });
            }
        }
        self.fail_cache = Some((demand.clone(), cluster.grow_epoch()));
        self.stats.failed += 1;
        None
    }

    /// Centralized baseline (for the C3 scaling ablation): always scans
    /// every node from zero and picks the least-loaded fit — the
    /// "central bottleneck" policy the paper contrasts with.
    pub fn place_centralized(
        &mut self,
        cluster: &mut Cluster,
        demand: &Resources,
    ) -> Option<Placement> {
        let mut best: Option<(NodeId, f64)> = None;
        for n in cluster.nodes.iter() {
            if n.alive && !n.draining && n.available.fits(demand) {
                let load = n.utilization_cpu();
                if best.map_or(true, |(_, b)| load < b) {
                    best = Some((n.id, load));
                }
            }
        }
        match best {
            Some((id, _)) => {
                let lease = cluster.lease(id, demand.clone());
                self.stats.spilled += 1;
                Some(Placement { node: id, lease, spilled: true })
            }
            None => {
                self.stats.failed += 1;
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefers_local() {
        let mut c = Cluster::uniform(3, Resources::cpu(2.0));
        let mut s = TwoLevelScheduler::new();
        let p = s.place(&mut c, 1, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
        assert!(!p.spilled);
        assert_eq!(s.stats.local, 1);
    }

    #[test]
    fn spills_on_local_exhaustion() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        let mut s = TwoLevelScheduler::new();
        let _ = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        let p = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
        assert!(p.spilled);
        assert_eq!(s.stats.spill_fraction(), 0.5);
    }

    #[test]
    fn fails_when_full() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        let mut s = TwoLevelScheduler::new();
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_some());
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_some());
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
        assert_eq!(s.stats.failed, 1);
    }

    #[test]
    fn fail_cache_clears_when_capacity_frees() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        let mut s = TwoLevelScheduler::new();
        let p = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert!(s.place(&mut c, 1, &Resources::cpu(1.0)).is_some());
        // Saturated: the first miss scans, repeats hit the memo — both
        // still count as failures.
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
        assert_eq!(s.stats.failed, 2);
        // A release bumps the grow epoch, so placement works again.
        c.release(p.node, p.lease);
        let q = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(q.node, 0);
        // A different demand never hits the memo.
        assert!(s.place(&mut c, 0, &Resources::cpu(0.5)).is_none());
        assert!(s.place(&mut c, 0, &Resources::cpu(0.25)).is_none());
        assert_eq!(s.stats.failed, 4);
    }

    #[test]
    fn skips_dead_nodes() {
        let mut c = Cluster::uniform(2, Resources::cpu(1.0));
        c.kill_node(0);
        let mut s = TwoLevelScheduler::new();
        let p = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
    }

    #[test]
    fn skips_draining_nodes() {
        let mut c = Cluster::uniform(2, Resources::cpu(2.0));
        c.begin_drain(0);
        let mut s = TwoLevelScheduler::new();
        let p = s.place(&mut c, 0, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
        // Node 1 still has free capacity, but draining blocks it too.
        c.begin_drain(1);
        assert!(s.place(&mut c, 0, &Resources::cpu(1.0)).is_none());
    }

    #[test]
    fn centralized_picks_least_loaded() {
        let mut c = Cluster::uniform(2, Resources::cpu(4.0));
        let mut s = TwoLevelScheduler::new();
        c.lease(0, Resources::cpu(3.0));
        let p = s.place_centralized(&mut c, &Resources::cpu(1.0)).unwrap();
        assert_eq!(p.node, 1);
    }
}
