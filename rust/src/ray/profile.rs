//! Online throughput profiling per (workload class, node shape) — the
//! SHADHO policy (arxiv 1707.01428) on top of PR 5's mechanism.
//!
//! Hardware-aware scheduling needs one number: how many steps/sec does
//! workload `w` sustain on a node of shape `s`? The profiler learns it
//! online as an EWMA over observed step durations fed from the runner's
//! result events, with a deterministic cold-start prior so placement and
//! autoscaling behave identically on every executor before any sample
//! arrives. Profiles are runner state, exactly like autoscaler pressure:
//! they snapshot and restore, so a resumed run keeps what it learned.
//!
//! The sim side of the story is [`ShapeFactors`]: a planted table of
//! step-time multipliers per (workload, shape) that the `SimExecutor`
//! applies on the virtual clock, making fast/slow hardware classes fully
//! testable offline — the tests assert the profiler recovers the planted
//! ordering.

use std::collections::BTreeMap;

use super::resources::Resources;
use crate::util::json::Json;

/// EWMA smoothing factor for throughput observations: new samples move
/// the estimate by 30%, so a profile tracks drift without thrashing on
/// one noisy step.
const EWMA_ALPHA: f64 = 0.3;

/// Samples before a profile counts as warm (predictions before that
/// fall back to the prior).
const WARMUP_SAMPLES: u64 = 3;

/// Deterministic cold-start prior, in steps/sec. A constant (rather
/// than, say, a capacity heuristic) keeps equal-shape templates exactly
/// tied on predicted throughput, so cold cost-aware decisions reduce to
/// price alone — deterministic and testable.
const COLD_PRIOR: f64 = 1.0;

/// Canonical string key for a node shape, stable across runs and
/// executors: `"c{cpu}g{gpu}"` plus `",{name}{amount}"` per custom
/// dimension in `BTreeMap` (sorted) order. `f64` `Display` is
/// shortest-roundtrip in Rust, so equal capacities always render the
/// same key. [`Resources`] itself has EPS-tolerant equality and must
/// never be a map key — this is the one sanctioned flattening.
pub fn shape_key(r: &Resources) -> String {
    use std::fmt::Write as _;
    let mut k = format!("c{}g{}", r.cpu, r.gpu);
    for (name, amount) in &r.custom {
        let _ = write!(k, ",{name}{amount}");
    }
    k
}

/// Opportunity cost of parking `demand` on a node of shape `shape`:
/// the largest capacity fraction the demand consumes across dimensions
/// (floored at 1e-6 so tiny demands don't divide scores to infinity),
/// plus a +1.0 penalty for every scarce dimension the node has (GPU or
/// a custom accelerator) that the demand leaves idle. The penalty is
/// what stops CPU-bound work from squatting on GPU shapes: a CPU trial
/// on a GPU node blocks capacity a GPU-favored trial needs.
pub fn opportunity_cost(demand: &Resources, shape: &Resources) -> f64 {
    let mut frac: f64 = 0.0;
    if shape.cpu > 0.0 {
        frac = frac.max(demand.cpu / shape.cpu);
    }
    if shape.gpu > 0.0 {
        frac = frac.max(demand.gpu / shape.gpu);
    }
    for (k, cap) in &shape.custom {
        if *cap > 0.0 {
            let want = demand.custom.get(k).copied().unwrap_or(0.0);
            frac = frac.max(want / cap);
        }
    }
    let mut cost = frac.max(1e-6);
    if shape.gpu > 0.0 && demand.gpu <= 0.0 {
        cost += 1.0;
    }
    for (k, cap) in &shape.custom {
        if *cap > 0.0 && demand.custom.get(k).copied().unwrap_or(0.0) <= 0.0 {
            cost += 1.0;
        }
    }
    cost
}

/// One learned (workload, shape) throughput estimate.
#[derive(Clone, Copy, Debug)]
struct Profile {
    /// EWMA of observed steps/sec.
    ewma: f64,
    /// Observations folded in so far.
    samples: u64,
}

/// Online per-(workload class, node shape) throughput profiles: EWMA of
/// observed steps/sec with a deterministic cold-start prior and
/// snapshot/restore. Owned by the runner; fed from result events.
#[derive(Clone, Debug, Default)]
pub struct ThroughputProfiler {
    /// (workload class, shape key) -> learned profile. `BTreeMap` keeps
    /// iteration deterministic for snapshots and fleet scores.
    profiles: BTreeMap<(String, String), Profile>,
}

impl ThroughputProfiler {
    /// A fresh, fully cold profiler.
    pub fn new() -> Self {
        Self::default()
    }

    /// The deterministic cold-start prediction (steps/sec).
    pub fn prior() -> f64 {
        COLD_PRIOR
    }

    /// Fold in one observed step: workload `workload` took `step_secs`
    /// of virtual time on a node of shape `shape`. Non-finite or
    /// non-positive durations are dropped — a NaN step time must never
    /// poison a profile (it would propagate through every placement
    /// score thereafter).
    pub fn observe(&mut self, workload: &str, shape: &str, step_secs: f64) {
        if !step_secs.is_finite() || step_secs <= 0.0 {
            return;
        }
        let sps = 1.0 / step_secs;
        let key = (workload.to_string(), shape.to_string());
        match self.profiles.get_mut(&key) {
            Some(p) => {
                p.ewma = EWMA_ALPHA * sps + (1.0 - EWMA_ALPHA) * p.ewma;
                p.samples += 1;
            }
            None => {
                self.profiles.insert(key, Profile { ewma: sps, samples: 1 });
            }
        }
    }

    /// Warm prediction for (workload, shape) in steps/sec, or `None`
    /// until the profile has [`WARMUP_SAMPLES`] observations.
    pub fn predict(&self, workload: &str, shape: &str) -> Option<f64> {
        self.profiles
            .get(&(workload.to_string(), shape.to_string()))
            .filter(|p| p.samples >= WARMUP_SAMPLES)
            .map(|p| p.ewma)
    }

    /// [`predict`](Self::predict) with the cold-start prior as the
    /// fallback — the total function placement ranks with.
    pub fn predict_or_prior(&self, workload: &str, shape: &str) -> f64 {
        self.predict(workload, shape).unwrap_or(COLD_PRIOR)
    }

    /// True once `workload` has warm profiles on at least two distinct
    /// shapes — before that, ranking shapes against each other is just
    /// the prior comparing to itself, so callers stay on the cold
    /// (local-first) path.
    pub fn is_warm(&self, workload: &str) -> bool {
        self.profiles
            .range((workload.to_string(), String::new())..)
            .take_while(|((w, _), _)| w == workload)
            .filter(|(_, p)| p.samples >= WARMUP_SAMPLES)
            .count()
            >= 2
    }

    /// Fleet-level score for a shape: the mean warm prediction across
    /// all workload classes that have one on this shape, or the prior
    /// when none does. This is what the autoscaler's template choice
    /// consumes — "how fast is the current workload mix on this shape,
    /// as far as we know".
    pub fn fleet_score(&self, shape: &str) -> f64 {
        let mut sum = 0.0;
        let mut n = 0u64;
        for ((_, s), p) in &self.profiles {
            if s == shape && p.samples >= WARMUP_SAMPLES {
                sum += p.ewma;
                n += 1;
            }
        }
        if n == 0 {
            COLD_PRIOR
        } else {
            sum / n as f64
        }
    }

    /// Serialize every profile for the experiment snapshot.
    pub fn snapshot(&self) -> Json {
        let mut by_workload: BTreeMap<String, Vec<(String, Json)>> = BTreeMap::new();
        for ((w, s), p) in &self.profiles {
            by_workload.entry(w.clone()).or_default().push((
                s.clone(),
                Json::obj(vec![
                    ("ewma", Json::Num(p.ewma)),
                    ("samples", Json::Num(p.samples as f64)),
                ]),
            ));
        }
        Json::Obj(
            by_workload
                .into_iter()
                .map(|(w, shapes)| {
                    (w, Json::Obj(shapes.into_iter().collect()))
                })
                .collect(),
        )
    }

    /// Rebuild from a [`ThroughputProfiler::snapshot`] value.
    pub fn restore(&mut self, snap: &Json) -> Result<(), String> {
        let top = snap.as_obj().ok_or("profiler snapshot: expected object")?;
        let mut profiles = BTreeMap::new();
        for (w, shapes) in top {
            let shapes = shapes
                .as_obj()
                .ok_or("profiler snapshot: expected per-workload object")?;
            for (s, pj) in shapes {
                let ewma = pj
                    .get("ewma")
                    .and_then(|v| v.as_f64())
                    .ok_or("profiler snapshot: bad ewma")?;
                let samples = pj
                    .get("samples")
                    .and_then(|v| v.as_u64())
                    .ok_or("profiler snapshot: bad samples")?;
                profiles.insert((w.clone(), s.clone()), Profile { ewma, samples });
            }
        }
        self.profiles = profiles;
        Ok(())
    }
}

/// Planted step-time multipliers for the sim executor: rules of
/// (workload pattern, shape-key pattern, factor), first match wins,
/// `"*"` matches anything, default factor 1.0. A factor of 0.1 means
/// "this workload steps 10x faster on this shape" — the deterministic
/// stand-in for real fast/slow hardware classes, applied on the virtual
/// clock so every executor replays it identically.
#[derive(Clone, Debug, Default)]
pub struct ShapeFactors {
    rules: Vec<(String, String, f64)>,
}

impl ShapeFactors {
    /// An empty table (every factor 1.0).
    pub fn new() -> Self {
        Self::default()
    }

    /// Append a rule; builder-style. `workload`/`shape` are exact
    /// strings or `"*"`.
    pub fn rule(mut self, workload: &str, shape: &str, factor: f64) -> Self {
        self.rules.push((workload.to_string(), shape.to_string(), factor));
        self
    }

    /// The step-time multiplier for (workload, shape): first matching
    /// rule, else 1.0.
    pub fn factor(&self, workload: &str, shape: &str) -> f64 {
        for (w, s, f) in &self.rules {
            if (w == "*" || w == workload) && (s == "*" || s == shape) {
                return *f;
            }
        }
        1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_keys_are_canonical() {
        assert_eq!(shape_key(&Resources::cpu(4.0)), "c4g0");
        assert_eq!(shape_key(&Resources::cpu_gpu(8.0, 2.0)), "c8g2");
        assert_eq!(shape_key(&Resources::cpu_gpu(8.0, 0.5)), "c8g0.5");
        assert_eq!(
            shape_key(&Resources::cpu(4.0).with_custom("tpu", 2.0)),
            "c4g0,tpu2"
        );
        // Equal shapes always render equal keys (f64 Display is
        // shortest-roundtrip), so keys are usable where EPS-tolerant
        // Resources equality is not.
        assert_eq!(
            shape_key(&Resources::cpu_gpu(8.0, 4.0)),
            shape_key(&Resources::cpu_gpu(8.0, 4.0))
        );
    }

    #[test]
    fn ewma_learns_planted_ordering() {
        let mut p = ThroughputProfiler::new();
        let (fast, slow) = ("c8g2", "c8g0");
        for _ in 0..5 {
            p.observe("w", fast, 0.1); // 10 steps/sec
            p.observe("w", slow, 1.0); // 1 step/sec
        }
        let f = p.predict("w", fast).unwrap();
        let s = p.predict("w", slow).unwrap();
        assert!(f > s, "learned ordering inverted: fast {f} vs slow {s}");
        assert!(p.is_warm("w"));
        assert!(!p.is_warm("other"));
    }

    #[test]
    fn cold_profiles_fall_back_to_the_prior() {
        let mut p = ThroughputProfiler::new();
        assert_eq!(p.predict("w", "c4g0"), None);
        assert_eq!(p.predict_or_prior("w", "c4g0"), ThroughputProfiler::prior());
        // Two samples: still below warmup.
        p.observe("w", "c4g0", 0.5);
        p.observe("w", "c4g0", 0.5);
        assert_eq!(p.predict("w", "c4g0"), None);
        assert!(!p.is_warm("w"));
        p.observe("w", "c4g0", 0.5);
        assert!(p.predict("w", "c4g0").is_some());
        // One warm shape is still not "warm enough to rank".
        assert!(!p.is_warm("w"));
    }

    #[test]
    fn nan_and_garbage_steps_never_poison_profiles() {
        let mut p = ThroughputProfiler::new();
        for _ in 0..4 {
            p.observe("w", "c4g0", 0.25);
        }
        let before = p.predict("w", "c4g0").unwrap();
        p.observe("w", "c4g0", f64::NAN);
        p.observe("w", "c4g0", 0.0);
        p.observe("w", "c4g0", -1.0);
        p.observe("w", "c4g0", f64::INFINITY);
        assert_eq!(p.predict("w", "c4g0").unwrap().to_bits(), before.to_bits());
    }

    #[test]
    fn snapshot_roundtrip_is_exact() {
        let mut p = ThroughputProfiler::new();
        for i in 1..6 {
            p.observe("a", "c4g0", 0.1 * i as f64);
            p.observe("b", "c8g2", 0.2);
        }
        let text = p.snapshot().to_string();
        let mut q = ThroughputProfiler::new();
        q.restore(&crate::util::json::parse(&text).unwrap()).unwrap();
        assert_eq!(
            p.predict("a", "c4g0").unwrap().to_bits(),
            q.predict("a", "c4g0").unwrap().to_bits()
        );
        assert_eq!(q.predict("b", "c8g2").is_some(), p.predict("b", "c8g2").is_some());
        assert_eq!(q.fleet_score("c8g2").to_bits(), p.fleet_score("c8g2").to_bits());
    }

    #[test]
    fn fleet_score_averages_warm_workloads() {
        let mut p = ThroughputProfiler::new();
        assert_eq!(p.fleet_score("c4g0"), ThroughputProfiler::prior());
        for _ in 0..4 {
            p.observe("a", "c4g0", 0.5); // 2 steps/sec
            p.observe("b", "c4g0", 0.25); // 4 steps/sec
            p.observe("cold", "c8g2", 1.0);
        }
        let s = p.fleet_score("c4g0");
        assert!(s > 2.0 && s < 4.0, "mean of warm predictions expected, got {s}");
    }

    #[test]
    fn opportunity_cost_penalizes_idle_scarce_dimensions() {
        let gpu = Resources::cpu_gpu(4.0, 2.0);
        let cpu = Resources::cpu(4.0);
        let cpu_demand = Resources::cpu(1.0);
        let gpu_demand = Resources::cpu_gpu(1.0, 1.0);
        // CPU work on a GPU shape pays the idle-GPU penalty.
        assert!(opportunity_cost(&cpu_demand, &gpu) > 1.0);
        assert!(opportunity_cost(&cpu_demand, &cpu) < 1.0);
        // GPU work on the GPU shape pays only its capacity fraction.
        let c = opportunity_cost(&gpu_demand, &gpu);
        assert!((c - 0.5).abs() < 1e-9, "gpu demand should cost its gpu fraction, got {c}");
        // Idle custom accelerators penalize too.
        let tpu = Resources::cpu(4.0).with_custom("tpu", 2.0);
        assert!(opportunity_cost(&cpu_demand, &tpu) > 1.0);
    }

    #[test]
    fn shape_factor_rules_first_match_and_wildcards() {
        let f = ShapeFactors::new()
            .rule("gpu_heavy", "c8g2", 0.1)
            .rule("gpu_heavy", "*", 2.0)
            .rule("*", "c8g2", 0.5);
        assert_eq!(f.factor("gpu_heavy", "c8g2"), 0.1);
        assert_eq!(f.factor("gpu_heavy", "c4g0"), 2.0);
        assert_eq!(f.factor("other", "c8g2"), 0.5);
        assert_eq!(f.factor("other", "c4g0"), 1.0);
    }
}
