//! Minimal JSON parser/serializer (no serde in the offline dep set).
//!
//! Covers the full JSON grammar we exchange with the build-time python
//! layer (`artifacts/manifest.json`) and the JSONL result logs: objects,
//! arrays, strings with escapes, numbers, booleans, null. Numbers are
//! held as f64 (all our payloads — shapes, metrics, counts — fit
//! losslessly below 2^53).

// The unwraps here are deliberate — lock poisoning is unrecoverable, and
// the rest guard build-time-validated invariants. The file opts out of the
// workspace `-D clippy::unwrap_used` gate; lint.toml's panic budgets still
// cap the hot-path files.
#![allow(clippy::unwrap_used)]

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value; numbers are f64, objects are ordered maps.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (ordered).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Number view.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Number view truncated to u64.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }
    /// String view.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Boolean view.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Array view.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object view.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Path lookup: `get("models.mlp_relu.train_hlo")`.
    pub fn get(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.as_obj()?.get(part)?;
        }
        Some(cur)
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize to compact JSON text.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize into a caller-provided buffer — the streaming encoder
    /// the JSONL hot path uses to reuse one allocation across lines.
    pub fn write_to(&self, out: &mut String) {
        self.write(out);
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_json_f64(*n, out),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Append the JSON encoding of an `f64` to `out` — integers below 1e15
/// print exactly (no `.0`), non-finite values become `null` (JSON has no
/// NaN/Inf). This is [`Json::Num`]'s formatting, exposed so streaming
/// encoders produce byte-identical output without building a [`Json`].
pub fn write_json_f64(n: f64, out: &mut String) {
    if n.is_finite() {
        if n == n.trunc() && n.abs() < 1e15 {
            let _ = write!(out, "{}", n as i64);
        } else {
            let _ = write!(out, "{n}");
        }
    } else {
        out.push_str("null");
    }
}

/// Append the JSON string encoding (quotes + escapes) of `s` to `out` —
/// [`Json::Str`]'s formatting for streaming encoders.
pub fn write_json_str(s: &str, out: &mut String) {
    write_escaped(s, out);
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document (trailing data is an error).
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }
    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        self.pos += 1;
        b
    }
    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }
    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos - 1))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos - 1)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos - 1)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err("unterminated string".into()),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or("bad \\u escape")? as char;
                            code = code * 16 + c.to_digit(16).ok_or("bad hex in \\u")?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err("bad escape".into()),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| "bad utf8".to_string())?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

/// Encode a `u64` losslessly (hex string — [`Json::Num`] is an f64 and
/// would corrupt values above 2^53, e.g. RNG states and trial seeds).
pub fn u64_to_json(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

/// Decode a `u64` written by [`u64_to_json`].
pub fn u64_from_json(j: &Json) -> Option<u64> {
    match j {
        Json::Str(s) => u64::from_str_radix(s, 16).ok(),
        // Tolerate plain numbers (small counters round-trip exactly).
        Json::Num(n) if *n >= 0.0 => Some(*n as u64),
        _ => None,
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": -1.5e3}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_f64(), Some(-1500.0));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn path_get() {
        let v = parse(r#"{"models": {"m": {"train_hlo": "f.txt"}}}"#).unwrap();
        assert_eq!(v.get("models.m.train_hlo").unwrap().as_str(), Some("f.txt"));
        assert!(v.get("models.q").is_none());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ☃"));
        let u = parse(r#""☃""#).unwrap();
        assert_eq!(u.as_str(), Some("☃"));
    }

    #[test]
    fn rejects_garbage() {
        for s in ["{", "[1,", "\"x", "{\"a\" 1}", "tru", "1 2"] {
            assert!(parse(s).is_err(), "{s}");
        }
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn streaming_writers_match_tree_encoding() {
        for n in [0.0, -1.0, 42.0, 0.5, -1.5e3, 1e16, f64::NAN, f64::INFINITY] {
            let mut buf = String::new();
            write_json_f64(n, &mut buf);
            assert_eq!(buf, Json::Num(n).to_string(), "{n}");
        }
        let mut buf = String::new();
        write_json_str("a\"b\\c\nd\u{1}", &mut buf);
        assert_eq!(buf, Json::Str("a\"b\\c\nd\u{1}".into()).to_string());
        let v = parse(r#"{"a":[1,{"b":"x"}],"c":-1.5}"#).unwrap();
        let mut buf = String::from("seed:");
        v.write_to(&mut buf);
        assert_eq!(buf, format!("seed:{}", v.to_string()));
    }
}
