//! Metric-name interning: the id ↔ name table behind the allocation-lean
//! result hot path.
//!
//! Trainables report metrics by name (`"accuracy"`, `"loss"`), but the
//! coordinator consumes millions of result rows per experiment and the
//! set of distinct names is tiny and stable. A [`MetricSchema`] interns
//! each name once per experiment; everything downstream of the executor
//! boundary — [`crate::coordinator::trial::ResultRow`], schedulers,
//! loggers, persistence — carries a compact [`MetricId`] instead of a
//! heap-allocated string key, so per-result work is integer compares and
//! memcpys, not `BTreeMap<String, _>` churn.
//!
//! Ids are **process-ephemeral**: snapshots and JSONL logs always write
//! metric *names* (robust, human-readable, schema-evolution-proof) and
//! re-intern on load, so the on-disk formats are unchanged and ids never
//! need to survive a restart.

use std::collections::HashMap;

/// Compact per-experiment identifier of a metric name.
pub type MetricId = u32;

/// Bidirectional metric-name table: `intern` is amortized O(1) with no
/// allocation for already-known names (the steady state after the first
/// result of an experiment).
#[derive(Clone, Debug, Default)]
pub struct MetricSchema {
    names: Vec<String>,
    /// lint:allow(hash_container): keyed lookup only, never iterated —
    /// enumeration order comes from `names`, which is insertion-ordered.
    index: HashMap<String, MetricId>,
}

impl MetricSchema {
    /// An empty schema.
    pub fn new() -> Self {
        Self::default()
    }

    /// Id of `name`, interning it on first sight. Steady state (name
    /// already known) is a hash lookup with zero allocations.
    pub fn intern(&mut self, name: &str) -> MetricId {
        if let Some(id) = self.index.get(name) {
            return *id;
        }
        let id = self.names.len() as MetricId;
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), id);
        id
    }

    /// Id of `name` if it has been interned (read-only view).
    pub fn lookup(&self, name: &str) -> Option<MetricId> {
        self.index.get(name).copied()
    }

    /// Name behind an id (None for ids this schema never issued).
    pub fn name(&self, id: MetricId) -> Option<&str> {
        self.names.get(id as usize).map(|s| s.as_str())
    }

    /// Number of interned names.
    pub fn len(&self) -> usize {
        self.names.len()
    }

    /// True when no names have been interned yet.
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn intern_is_stable_and_dense() {
        let mut s = MetricSchema::new();
        let a = s.intern("accuracy");
        let b = s.intern("loss");
        assert_eq!(a, 0);
        assert_eq!(b, 1);
        assert_eq!(s.intern("accuracy"), a);
        assert_eq!(s.len(), 2);
        assert_eq!(s.name(a), Some("accuracy"));
        assert_eq!(s.name(7), None);
        assert_eq!(s.lookup("loss"), Some(b));
        assert_eq!(s.lookup("nope"), None);
    }
}
