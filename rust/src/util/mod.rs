//! Shared infrastructure: JSON, metric-name interning, deterministic
//! RNG, NaN-proof metric ordering, micro-bench harness, property-test
//! harness, and the Table-1 LoC counter.

pub mod bench;
pub mod intern;
pub mod json;
pub mod loc;
pub mod order;
pub mod prop;
pub mod rng;
