//! Deterministic PRNG (SplitMix64) + distribution helpers.
//!
//! Every stochastic component in the coordinator (search-space sampling,
//! TPE, PBT perturbation, synthetic trainables, fault injection) draws
//! from an explicitly-seeded `Rng`, so whole experiments replay
//! bit-identically — a property the integration tests and benches rely
//! on. No external `rand` crate in the offline dep set; SplitMix64 is
//! tiny, fast and passes BigCrush.

/// SplitMix64 PRNG with distribution helpers.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded generator (same seed, same stream).
    pub fn new(seed: u64) -> Self {
        Rng {
            // Avoid the all-zero fixed point and decorrelate small seeds.
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    /// Derive an independent stream (e.g. one per trial) from this one.
    pub fn fork(&mut self, salt: u64) -> Rng {
        Rng::new(self.next_u64() ^ salt.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    /// The raw generator state, for snapshot/resume. Restoring it with
    /// [`Rng::set_state`] continues the stream exactly where it left off.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Overwrite the generator state (see [`Rng::state`]).
    pub fn set_state(&mut self, state: u64) {
        self.state = state;
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Log-uniform in [lo, hi), lo > 0.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi > lo);
        (self.uniform(lo.ln(), hi.ln())).exp()
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as i64
    }

    /// Index in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Biased coin flip: true with probability `p`.
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal draw with mean `mu` and standard deviation `sigma`.
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.index(i + 1);
            v.swap(i, j);
        }
    }

    /// Uniformly pick one element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.index(v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_roundtrip_resumes_stream() {
        let mut a = Rng::new(11);
        a.next_u64();
        let saved = a.state();
        let expect: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let mut b = Rng::new(0);
        b.set_state(saved);
        let got: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        assert_eq!(expect, got);
    }

    #[test]
    fn seeds_decorrelate() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(4);
        let mean: f64 = (0..20_000).map(|_| r.uniform(2.0, 4.0)).sum::<f64>() / 20_000.0;
        assert!((mean - 3.0).abs() < 0.02, "{mean}");
    }

    #[test]
    fn log_uniform_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..5_000 {
            let x = r.log_uniform(1e-4, 1e-1);
            assert!((1e-4..1e-1).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn range_hits_all_values() {
        let mut r = Rng::new(8);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[r.range(0, 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..20).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..20).collect::<Vec<_>>());
        assert_ne!(v, (0..20).collect::<Vec<_>>());
    }
}
