//! Tiny property-based testing harness (proptest is not in the offline
//! dep set).
//!
//! [`check`] runs a property over `cases` deterministic random cases; on
//! the first failure it panics with the case index and the per-case seed
//! so the exact input can be replayed with [`replay`]. Generators are
//! plain closures over [`Rng`], which composes naturally with the
//! library's own deterministic-seeding discipline.

use super::rng::Rng;

/// Run `prop` on `cases` random cases derived from `seed`.
/// `prop` gets a per-case RNG and the case index; it should panic (e.g.
/// via assert!) on violation.
pub fn check<F: FnMut(&mut Rng, usize)>(name: &str, seed: u64, cases: usize, mut prop: F) {
    for i in 0..cases {
        let mut rng = Rng::new(seed ^ (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng, i);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {i} (replay with seed={seed}, case={i}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case.
pub fn replay<F: FnMut(&mut Rng, usize)>(seed: u64, case: usize, mut prop: F) {
    let mut rng = Rng::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15));
    prop(&mut rng, case);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("unit_interval", 1, 200, |rng, _| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn failing_property_reports_case() {
        check("always_fails", 2, 10, |_, i| {
            assert!(i < 3, "boom at {i}");
        });
    }

    #[test]
    fn replay_matches_check_stream() {
        let mut seen = Vec::new();
        check("record", 3, 5, |rng, _| seen.push(rng.next_u64()));
        let mut replayed = 0;
        replay(3, 2, |rng, _| replayed = rng.next_u64());
        assert_eq!(replayed, seen[2]);
    }
}
