//! Micro-benchmark harness (criterion is not in the offline dep set).
//!
//! Plain-binary benches (`harness = false` in Cargo.toml) call
//! [`bench`] / [`bench_n`]: warm up, time `iters` runs, and report
//! min / median / mean / p95 per iteration plus derived throughput.
//! Output is one aligned row per case so `cargo bench` output can be
//! pasted straight into EXPERIMENTS.md.

use std::time::Instant;

/// Timing summary of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    /// Case label.
    pub name: String,
    /// Measured iterations.
    pub iters: usize,
    /// Fastest iteration, nanoseconds.
    pub min_ns: f64,
    /// Median iteration, nanoseconds.
    pub median_ns: f64,
    /// Mean iteration, nanoseconds.
    pub mean_ns: f64,
    /// 95th-percentile iteration, nanoseconds.
    pub p95_ns: f64,
}

impl BenchStats {
    /// Median per-iteration cost in milliseconds.
    pub fn per_iter_ms(&self) -> f64 {
        self.median_ns / 1e6
    }
    /// Print this row (same columns as [`header`]).
    pub fn report(&self) {
        println!(
            "{:<44} {:>10} {:>12} {:>12} {:>12}   ({} iters)",
            self.name,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns),
            self.iters,
        );
    }
}

/// Print the column header matching [`BenchStats::report`].
pub fn header() {
    println!(
        "{:<44} {:>10} {:>12} {:>12} {:>12}",
        "benchmark", "min", "median", "mean", "p95"
    );
    println!("{}", "-".repeat(96));
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench_n<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| crate::util::order::asc(*a, *b));
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        min_ns: samples[0],
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
    };
    stats.report();
    stats
}

/// Auto-calibrated variant: picks an iteration count that gives ~1s of
/// total measurement, bounded to [5, 200].
pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchStats {
    let t = Instant::now();
    f();
    let once = t.elapsed().as_nanos().max(1) as f64;
    let iters = ((1e9 / once) as usize).clamp(5, 200);
    bench_n(name, (iters / 10).max(1), iters, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_are_ordered() {
        let s = bench_n("noop", 2, 50, || { std::hint::black_box(1 + 1); });
        assert!(s.min_ns <= s.median_ns);
        assert!(s.median_ns <= s.p95_ns);
        assert_eq!(s.iters, 50);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("us"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with('s'));
    }
}
