//! NaN-proof total order over metric values.
//!
//! §4.2 of the paper requires the platform to "handle irregular
//! computations" — and the most common irregularity of a real training
//! job is a diverged loss: the trainable keeps stepping but reports
//! `NaN`. Every ranking site in the coordinator (ASHA rung cutoffs, PBT
//! population ranking, HyperBand rung cuts, median stopping, TPE's
//! good/bad split, evolution's parent pool, the runner's best-trial
//! pick) used to compare metrics with `partial_cmp(..).unwrap()`, so a
//! single `NaN` panicked the whole coordinator and took every other
//! trial — and, under the [`crate::coordinator::hub::ExperimentHub`],
//! every other *experiment* — down with it.
//!
//! This module is the one shared fix: a total order on `f64` that ranks
//! `NaN` strictly *worst*. All ranking sites normalize metrics with
//! [`crate::coordinator::trial::Mode::ascending`] first (higher is
//! always better), so "worst" uniformly means *smallest*: `NaN` sorts
//! below `-inf` in ascending order and last in best-first order. A
//! diverged trial therefore loses every comparison — it gets cut at
//! rungs, exploited by PBT, stopped by the median rule — instead of
//! crashing the scheduler.

use std::cmp::Ordering;

/// Ascending total order with `NaN` ranked strictly smallest (worst
/// after `Mode::ascending` normalization). Total: never panics.
pub fn asc(a: f64, b: f64) -> Ordering {
    match (a.is_nan(), b.is_nan()) {
        (true, true) => Ordering::Equal,
        (true, false) => Ordering::Less,
        (false, true) => Ordering::Greater,
        // Both are non-NaN: IEEE order, with -0.0 < +0.0 tie-broken by
        // total_cmp (irrelevant for rankings, but keeps Ord lawful).
        (false, false) => a.total_cmp(&b),
    }
}

/// Descending (best-first) total order with `NaN` ranked strictly last.
pub fn desc(a: f64, b: f64) -> Ordering {
    asc(b, a)
}

/// An `f64` wrapped in the [`asc`] total order, so metric values can
/// live in `BinaryHeap`s and other `Ord`-requiring structures — the
/// incremental order-statistics the schedulers keep per rung/iteration
/// are built on this. `NaN` ranks strictly smallest, like everywhere
/// else in the coordinator.
#[derive(Clone, Copy, Debug)]
pub struct OrdF64(
    /// The wrapped value.
    pub f64,
);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        asc(self.0, other.0) == Ordering::Equal
    }
}
impl Eq for OrdF64 {}
impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        asc(self.0, other.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nan_is_worst_in_both_directions() {
        assert_eq!(asc(f64::NAN, f64::NEG_INFINITY), Ordering::Less);
        assert_eq!(asc(f64::NEG_INFINITY, f64::NAN), Ordering::Greater);
        assert_eq!(asc(f64::NAN, f64::NAN), Ordering::Equal);
        assert_eq!(desc(f64::NAN, -1e300), Ordering::Greater); // sorts last
        assert_eq!(desc(-1e300, f64::NAN), Ordering::Less);
    }

    #[test]
    fn finite_values_order_normally() {
        assert_eq!(asc(1.0, 2.0), Ordering::Less);
        assert_eq!(asc(2.0, 1.0), Ordering::Greater);
        assert_eq!(asc(1.0, 1.0), Ordering::Equal);
        assert_eq!(desc(2.0, 1.0), Ordering::Less); // best first
    }

    #[test]
    fn sorting_puts_nan_last_in_best_first_lists() {
        let mut v = vec![0.3, f64::NAN, 0.9, f64::NAN, 0.1];
        v.sort_by(|a, b| desc(*a, *b));
        assert_eq!(v[0], 0.9);
        assert_eq!(v[1], 0.3);
        assert_eq!(v[2], 0.1);
        assert!(v[3].is_nan() && v[4].is_nan());
    }

    #[test]
    fn ordf64_is_heap_safe_with_nans() {
        let mut h = std::collections::BinaryHeap::new();
        for v in [0.3, f64::NAN, 0.9, f64::NEG_INFINITY] {
            h.push(OrdF64(v));
        }
        assert_eq!(h.pop().unwrap().0, 0.9); // max-heap, NaN never max
        assert_eq!(h.pop().unwrap().0, 0.3);
        assert_eq!(h.pop().unwrap().0, f64::NEG_INFINITY);
        assert!(h.pop().unwrap().0.is_nan()); // NaN drains last
    }

    #[test]
    fn select_nth_with_nans_does_not_panic() {
        let mut v = vec![f64::NAN, 0.5, f64::NAN, 0.7, 0.2];
        let (_, kth, _) = v.select_nth_unstable_by(1, |a, b| desc(*a, *b));
        assert_eq!(*kth, 0.5); // 2nd best of {0.7, 0.5, 0.2, NaN, NaN}
    }
}
