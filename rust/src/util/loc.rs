//! Source line counting for reproducing the paper's Table 1.
//!
//! Table 1 reports lines of code per trial-scheduling algorithm
//! implemented in Tune ("line counts include lines used for logging and
//! debugging"). We count the same way over our scheduler/search modules:
//! non-blank lines excluding pure comment/doc lines and the unit-test
//! blocks (the paper's python has its tests elsewhere; counting our
//! inline `#[cfg(test)]` modules would not be like-for-like).

/// Count algorithm LoC in one rust source string: non-blank, non-comment
/// lines up to (excluding) the `#[cfg(test)]` block.
pub fn algorithm_loc(source: &str) -> usize {
    let mut count = 0;
    let mut in_block_comment = false;
    for line in source.lines() {
        let t = line.trim();
        if t.contains("#[cfg(test)]") {
            break; // inline unit tests are not algorithm code
        }
        if in_block_comment {
            if t.contains("*/") {
                in_block_comment = false;
            }
            continue;
        }
        if t.is_empty() || t.starts_with("//") {
            continue;
        }
        if t.starts_with("/*") {
            if !t.contains("*/") {
                in_block_comment = true;
            }
            continue;
        }
        count += 1;
    }
    count
}

/// One row of the regenerated Table 1.
#[derive(Debug, Clone)]
pub struct LocRow {
    /// Algorithm name as printed in the paper.
    pub algorithm: &'static str,
    /// LoC the paper reports for its python implementation.
    pub paper_loc: usize,
    /// Our source files implementing the algorithm.
    pub files: Vec<&'static str>,
    /// Our LoC counted the paper's way.
    pub our_loc: usize,
}

/// Regenerate Table 1 from the shipped source tree (paths relative to
/// the repo root; falls back to CARGO_MANIFEST_DIR when run from
/// elsewhere).
pub fn table1(repo_root: &std::path::Path) -> Vec<LocRow> {
    let spec: Vec<(&'static str, usize, Vec<&'static str>)> = vec![
        ("FIFO (trivial scheduler)", 10, vec!["rust/src/coordinator/schedulers/fifo.rs"]),
        ("Asynchronous HyperBand", 78, vec!["rust/src/coordinator/schedulers/asha.rs"]),
        ("HyperBand", 215, vec!["rust/src/coordinator/schedulers/hyperband.rs"]),
        ("Median Stopping Rule", 68, vec!["rust/src/coordinator/schedulers/median_stopping.rs"]),
        ("HyperOpt (TPE search)", 137, vec!["rust/src/coordinator/search/tpe.rs"]),
        ("Population-Based Training", 169, vec!["rust/src/coordinator/schedulers/pbt.rs"]),
    ];
    spec.into_iter()
        .map(|(algorithm, paper_loc, files)| {
            let our_loc = files
                .iter()
                .map(|f| {
                    std::fs::read_to_string(repo_root.join(f))
                        .map(|s| algorithm_loc(&s))
                        .unwrap_or(0)
                })
                .sum();
            LocRow { algorithm, paper_loc, files, our_loc }
        })
        .collect()
}

/// Print Table 1 (paper LoC vs ours) to stdout.
pub fn print_table1(rows: &[LocRow]) {
    println!("Table 1 — model selection algorithms: lines of code");
    println!("{:<28} {:>10} {:>10}", "Algorithm", "paper", "ours");
    println!("{}", "-".repeat(52));
    for r in rows {
        println!("{:<28} {:>10} {:>10}", r.algorithm, r.paper_loc, r.our_loc);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_code_not_comments() {
        let src = "// comment\n\nfn f() {\n    let x = 1; // inline\n}\n/* block\n   comment */\nfn g() {}\n";
        assert_eq!(algorithm_loc(src), 4);
    }

    #[test]
    fn stops_at_test_module() {
        let src = "fn f() {}\n#[cfg(test)]\nmod tests {\n fn t() {}\n}\n";
        assert_eq!(algorithm_loc(src), 1);
    }

    #[test]
    fn table_has_all_six_rows() {
        let rows = table1(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
        assert_eq!(rows.len(), 6);
        assert_eq!(rows.iter().map(|r| r.paper_loc).sum::<usize>(), 10 + 78 + 215 + 68 + 137 + 169);
    }
}
