//! C8 bench: the content-addressed checkpoint store under a PBT
//! workload — save/restore throughput, dedup ratio, and spill traffic.
//!
//! Run: `cargo bench --bench ckpt_store`
//!
//! Two cases:
//!  * `store_pbt` drives `CheckpointStore` directly with the shape PBT
//!    produces — per-round small mutations of large weight blobs,
//!    bottom-quantile trials cloning top-quantile checkpoints — with
//!    the spill tier and a memory budget active, then measures restore
//!    bandwidth by evicting everything and reading every live blob
//!    back from chunks.
//!  * `runner_pbt` runs a real PBT experiment through the coordinator
//!    with a big-state trainable and reports the store counters the
//!    runner surfaces in `ExperimentResult::ckpt`.
//!
//! `TUNE_BENCH_FAST=1` shrinks blob sizes and round counts so CI can
//! smoke the binary in seconds; the emitted `BENCH_ckpt_store.json`
//! records which mode produced the numbers.

use std::path::PathBuf;
use std::time::Instant;

use tune::checkpoint::CheckpointStore;
use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::trial::Config;
use tune::coordinator::{
    run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::{factory, StepOutput, Trainable};
use tune::util::json::Json;
use tune::util::rng::Rng;

const MIB: f64 = 1024.0 * 1024.0;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tune_bench_ckpt_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    std::fs::create_dir_all(&d).unwrap();
    d
}

struct StoreCase {
    save_mb_s: f64,
    restore_mb_s: f64,
    dedup_ratio: f64,
    logical_mib: f64,
    physical_mib: f64,
    unique_chunks: u64,
    blob_dedup_hits: u64,
    spilled_chunks: u64,
}

/// Store-level PBT simulation: `trials` populations of `blob` bytes of
/// "weights"; each round every trial perturbs a 4 KiB window and
/// checkpoints; from round 2 on, the bottom half exploits (clones the
/// state of) a top-quartile trial before perturbing — the lineage
/// convergence that makes real PBT checkpoint sets collapse under
/// content addressing.
fn store_pbt(trials: usize, blob: usize, rounds: usize) -> StoreCase {
    let dir = tmpdir("store");
    let mut store = CheckpointStore::new().with_disk(dir.clone());
    store.keep_per_trial = 2;
    store.set_mem_budget(Some(8 << 20));
    let mut rng = Rng::new(0xBE7C);
    let mut state: Vec<Vec<u8>> = (0..trials)
        .map(|t| (0..blob).map(|i| (i as u64 * 31 + t as u64) as u8).collect())
        .collect();

    let mut saved_bytes = 0u64;
    let mut save_time = 0.0f64;
    for round in 0..rounds {
        // Exploit phase: the bottom half clones a top-quartile trial's
        // latest checkpoint (a shuffle stands in for the score ranking;
        // the storage shape is what's measured). Like the runner, the
        // exploiter checkpoints the cloned state verbatim — the
        // whole-blob dedup fast path — before perturbing it.
        if round >= 2 {
            let mut order: Vec<usize> = (0..trials).collect();
            rng.shuffle(&mut order);
            let (top, rest) = order.split_at(trials / 4);
            for &loser in &rest[trials / 4..] {
                let winner = *rng.choose(top);
                if let Some(cid) = store.latest_for(winner as u64) {
                    if let Some(cloned) = store.get(cid) {
                        state[loser] = cloned.to_vec();
                        saved_bytes += cloned.len() as u64;
                        let t0 = Instant::now();
                        store.save_timed(loser as u64, round as u64, round as f64, cloned);
                        save_time += t0.elapsed().as_secs_f64();
                    }
                }
            }
        }
        // Perturb + checkpoint phase.
        for t in 0..trials {
            let at = rng.index(state[t].len().saturating_sub(4096).max(1));
            let end = (at + 4096).min(state[t].len());
            for b in &mut state[t][at..end] {
                *b = b.wrapping_add(1);
            }
            let payload = state[t].clone();
            saved_bytes += payload.len() as u64;
            let t0 = Instant::now();
            store.save_timed(t as u64, round as u64 + 1, round as f64, payload);
            save_time += t0.elapsed().as_secs_f64();
        }
    }

    // Restore bandwidth: evict every resident byte (assembled caches
    // and chunk payloads both), then reassemble every live blob from
    // the spill tier.
    store.set_mem_budget(Some(0));
    store.set_mem_budget(None);
    let ids: Vec<u64> = store.ids().collect();
    let mut restored_bytes = 0u64;
    let t0 = Instant::now();
    for id in &ids {
        restored_bytes += store.get(*id).expect("live blob reads back").len() as u64;
    }
    let restore_time = t0.elapsed().as_secs_f64();

    let s = store.stats();
    std::fs::remove_dir_all(&dir).ok();
    StoreCase {
        save_mb_s: saved_bytes as f64 / MIB / save_time.max(1e-9),
        restore_mb_s: restored_bytes as f64 / MIB / restore_time.max(1e-9),
        dedup_ratio: s.dedup_ratio(),
        logical_mib: s.logical_bytes as f64 / MIB,
        physical_mib: s.physical_bytes as f64 / MIB,
        unique_chunks: s.unique_chunks,
        blob_dedup_hits: s.blob_dedup_hits,
        spilled_chunks: s.spilled_chunks,
    }
}

/// A trainable with PBT-shaped state: a large weight buffer of which
/// one step touches only a small window. `save` is the whole buffer —
/// exactly what makes naive checkpoint storage O(population x rounds x
/// weights) and the chunk store O(weights + edits).
struct BigStateTrainable {
    state: Vec<u8>,
    t: u64,
    quality: f64,
    lr: f64,
}

impl BigStateTrainable {
    fn new(config: &Config, seed: u64, bytes: usize) -> Self {
        let mut rng = Rng::new(seed ^ 0xB16_57A7E);
        let state = (0..bytes).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        BigStateTrainable {
            state,
            t: 0,
            quality: 0.0,
            lr: config.get("lr").and_then(|v| v.as_f64()).unwrap_or(0.01),
        }
    }
}

impl Trainable for BigStateTrainable {
    fn step(&mut self) -> Result<StepOutput, String> {
        self.t += 1;
        // One optimizer step dirties a deterministic 2 KiB window.
        let at = (self.t as usize * 2048) % self.state.len().saturating_sub(2048).max(1);
        let end = (at + 2048).min(self.state.len());
        for b in &mut self.state[at..end] {
            *b = b.wrapping_add(1);
        }
        self.quality += self.lr / (1.0 + self.lr * self.t as f64);
        Ok(StepOutput::of(&[("accuracy", self.quality)]))
    }
    fn save(&mut self) -> Vec<u8> {
        let mut blob = Vec::with_capacity(self.state.len() + 16);
        blob.extend_from_slice(&self.t.to_le_bytes());
        blob.extend_from_slice(&self.quality.to_le_bytes());
        blob.extend_from_slice(&self.state);
        blob
    }
    fn restore(&mut self, blob: &[u8]) -> Result<(), String> {
        if blob.len() < 16 {
            return Err("short blob".into());
        }
        self.t = u64::from_le_bytes(blob[..8].try_into().unwrap());
        self.quality = f64::from_le_bytes(blob[8..16].try_into().unwrap());
        self.state = blob[16..].to_vec();
        Ok(())
    }
    fn update_config(&mut self, config: &Config) {
        if let Some(lr) = config.get("lr").and_then(|v| v.as_f64()) {
            self.lr = lr;
        }
    }
}

struct RunnerCase {
    wall_s: f64,
    exploits: u64,
    saved: u64,
    dedup_ratio: f64,
    logical_mib: f64,
    physical_mib: f64,
    spilled_chunks: u64,
}

/// Runner-level PBT with the spill tier and memory budget on: the
/// numbers here are the store counters a real experiment reports.
fn runner_pbt(samples: usize, iters: u64, state_bytes: usize) -> RunnerCase {
    let dir = tmpdir("runner");
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-3, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();
    let mut spec = ExperimentSpec::named("ckpt-bench-pbt");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = 7;
    spec.checkpoint_freq = 2;
    let t0 = Instant::now();
    let res = run_experiments(
        spec,
        space.clone(),
        SchedulerKind::Pbt { perturbation_interval: 3, space },
        SearchKind::Random,
        factory(move |c, s| Box::new(BigStateTrainable::new(c, s, state_bytes))),
        RunOptions {
            cluster: Cluster::uniform(4, Resources::cpu(8.0)),
            experiment_dir: Some(dir.clone()),
            snapshot_every: 10,
            checkpoint_mem_budget: Some(4 << 20),
            ..Default::default()
        },
    );
    let wall_s = t0.elapsed().as_secs_f64();
    std::fs::remove_dir_all(&dir).ok();
    RunnerCase {
        wall_s,
        exploits: res.stats.exploits,
        saved: res.ckpt.saved,
        dedup_ratio: res.ckpt.dedup_ratio(),
        logical_mib: res.ckpt.logical_bytes as f64 / MIB,
        physical_mib: res.ckpt.physical_bytes as f64 / MIB,
        spilled_chunks: res.ckpt.spilled_chunks,
    }
}

fn main() {
    let fast = std::env::var("TUNE_BENCH_FAST").is_ok();
    let (blob, rounds) = if fast { (128 << 10, 6) } else { (1 << 20, 20) };
    let (samples, iters, state_bytes) = if fast { (8, 8, 64 << 10) } else { (16, 24, 256 << 10) };

    println!(
        "== content-addressed checkpoint store under PBT{} ==",
        if fast { " [FAST]" } else { "" }
    );

    let sc = store_pbt(16, blob, rounds);
    println!(
        "store_pbt   16 trials x {} rounds x {:.1} MiB blobs (spill + 8 MiB budget)",
        rounds,
        blob as f64 / MIB
    );
    println!(
        "  save {:.0} MB/s   restore {:.0} MB/s   dedup {:.1}x ({:.1} -> {:.1} MiB, {} chunks)",
        sc.save_mb_s, sc.restore_mb_s, sc.dedup_ratio, sc.logical_mib, sc.physical_mib,
        sc.unique_chunks
    );
    println!(
        "  blob-level exploit hits {}   chunks spilled {}",
        sc.blob_dedup_hits, sc.spilled_chunks
    );

    let rc = runner_pbt(samples, iters, state_bytes);
    println!(
        "runner_pbt  {} trials x {} iters x {} KiB state (PBT, ckpt every 2)",
        samples,
        iters,
        state_bytes >> 10
    );
    println!(
        "  wall {:.2}s   exploits {}   saves {}   dedup {:.1}x ({:.1} -> {:.1} MiB, {} spilled)",
        rc.wall_s, rc.exploits, rc.saved, rc.dedup_ratio, rc.logical_mib, rc.physical_mib,
        rc.spilled_chunks
    );

    let json = Json::obj(vec![
        ("bench", Json::Str("ckpt_store".into())),
        ("fast_mode", Json::Bool(fast)),
        (
            "store_pbt",
            Json::obj(vec![
                ("trials", Json::Num(16.0)),
                ("rounds", Json::Num(rounds as f64)),
                ("blob_bytes", Json::Num(blob as f64)),
                ("save_mb_s", Json::Num(sc.save_mb_s)),
                ("restore_mb_s", Json::Num(sc.restore_mb_s)),
                ("dedup_ratio", Json::Num(sc.dedup_ratio)),
                ("logical_mib", Json::Num(sc.logical_mib)),
                ("physical_mib", Json::Num(sc.physical_mib)),
                ("unique_chunks", Json::Num(sc.unique_chunks as f64)),
                ("blob_dedup_hits", Json::Num(sc.blob_dedup_hits as f64)),
                ("spilled_chunks", Json::Num(sc.spilled_chunks as f64)),
            ]),
        ),
        (
            "runner_pbt",
            Json::obj(vec![
                ("trials", Json::Num(samples as f64)),
                ("iters", Json::Num(iters as f64)),
                ("state_bytes", Json::Num(state_bytes as f64)),
                ("wall_s", Json::Num(rc.wall_s)),
                ("exploits", Json::Num(rc.exploits as f64)),
                ("saves", Json::Num(rc.saved as f64)),
                ("dedup_ratio", Json::Num(rc.dedup_ratio)),
                ("logical_mib", Json::Num(rc.logical_mib)),
                ("physical_mib", Json::Num(rc.physical_mib)),
                ("spilled_chunks", Json::Num(rc.spilled_chunks as f64)),
            ]),
        ),
    ]);
    match std::fs::write("BENCH_ckpt_store.json", json.to_string()) {
        Ok(()) => println!("\nwrote BENCH_ckpt_store.json"),
        Err(e) => eprintln!("\ncould not write BENCH_ckpt_store.json: {e}"),
    }
}
