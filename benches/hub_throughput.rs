//! Hub serving bench: experiments/sec and steady-state live-trial
//! occupancy when N experiments are multiplexed over ONE shared
//! 4-worker pool (1 / 4 / 16 concurrent experiments).
//!
//! What to look for:
//! * experiments/sec should grow with concurrency until the pool
//!   saturates — the hub's whole point is that serving 16 studies does
//!   not cost 16 pools;
//! * mean occupancy (live trials summed over experiments, sampled at
//!   every completion event) should sit near the global live-trial
//!   budget — fair-share admission keeps the pool busy even when each
//!   individual experiment is tiny.
//!
//! Run: `cargo bench --bench hub_throughput`

use tune::coordinator::hub::{ExperimentHub, Submission};
use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{ExperimentSpec, Mode, ParamValue, SchedulerKind, SearchKind};
use tune::trainable::factory;
use tune::trainable::synthetic::ConstTrainable;

const WORKERS: usize = 4;
const SAMPLES: usize = 16;
const ITERS: u64 = 8;

fn submission(name: &str, seed: u64) -> Submission {
    let mut spec = ExperimentSpec::named(name);
    spec.metric = "iters".into();
    spec.mode = Mode::Max;
    spec.num_samples = SAMPLES;
    spec.max_iterations_per_trial = ITERS;
    spec.seed = seed;
    let space = SpaceBuilder::new().constant("step_cost", ParamValue::F64(1.0)).build();
    Submission::new(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(ConstTrainable::new(c, s))),
    )
}

fn run_fleet(n: usize) -> (f64, f64, u64) {
    let mut hub = ExperimentHub::new(WORKERS, 4 * WORKERS);
    for i in 0..n {
        hub.submit(submission(&format!("bench-{i}"), i as u64)).expect("submit");
    }
    let t0 = std::time::Instant::now();
    let results = hub.run_all();
    let wall = t0.elapsed().as_secs_f64();
    assert_eq!(results.len(), n);
    let trials: u64 = results.iter().map(|(_, r)| r.trials.len() as u64).sum();
    (wall, hub.mean_occupancy(), trials)
}

fn main() {
    println!(
        "== hub throughput: {SAMPLES} trials x {ITERS} iters per experiment, \
         {WORKERS} workers, {} live-trial slots ==",
        4 * WORKERS
    );
    println!(
        "{:>12} {:>10} {:>12} {:>12} {:>14} {:>12}",
        "experiments", "wall(s)", "exps/sec", "trials/sec", "results/sec", "occupancy"
    );
    for n in [1usize, 4, 16] {
        let (wall, occupancy, trials) = run_fleet(n);
        let results = trials * ITERS;
        println!(
            "{:>12} {:>10.3} {:>12.2} {:>12.1} {:>14.0} {:>12.2}",
            n,
            wall,
            n as f64 / wall,
            trials as f64 / wall,
            results as f64 / wall,
            occupancy
        );
    }
}
