//! Hardware-aware scheduling bench: cost-aware vs cost-blind
//! autoscaling over 2 and 4 planted hardware classes (ISSUE 10, the
//! SHADHO-style claim). Everything runs on the sim executor's virtual
//! clock, so the numbers are deterministic offline proofs, not
//! wall-clock noise.
//!
//! Each class is a (shape, $/hour, step-time factor) triple; the
//! workload steps up to 10x faster on the accelerator shapes. The
//! cost-blind policy is the legacy first-fit template pick — it always
//! buys the default CPU shape. The cost-aware policy learns per-shape
//! throughput online and buys (and places onto) the shape with the
//! best predicted steps/sec per dollar.
//!
//! What to look for: with the same trial set, the aware policy should
//! finish in a fraction of the virtual makespan and pay less per
//! result; the gap should widen from 2 to 4 classes as the planted
//! hardware spread grows.
//!
//! `TUNE_BENCH_FAST=1` shrinks trials/iterations so CI can smoke the
//! binary in seconds; `BENCH_hw_sched.json` records which mode ran.
//!
//! Run: `cargo bench --bench hw_sched`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::trial::ParamValue;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{AutoscalePolicy, Cluster, NodeTemplate, Resources, ShapeFactors};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;
use tune::util::json::Json;

/// One planted hardware class: what the autoscaler can buy, what it
/// bills, and how fast the workload actually steps on it.
struct HwClass {
    name: &'static str,
    shape: Resources,
    price_per_hour: f64,
    step_factor: f64,
}

/// The class menu, default-CPU first (that is what the cost-blind
/// first-fit pick buys). Per-dollar throughput improves down the list,
/// so a policy that learns it should walk down.
fn classes() -> Vec<HwClass> {
    vec![
        HwClass {
            name: "cpu-small",
            shape: Resources::cpu(4.0),
            price_per_hour: 1.0,
            step_factor: 1.0,
        },
        HwClass {
            name: "cpu-big",
            shape: Resources::cpu(16.0),
            price_per_hour: 4.5,
            step_factor: 0.9,
        },
        HwClass {
            name: "gpu",
            shape: Resources::cpu_gpu(8.0, 4.0),
            price_per_hour: 6.0,
            step_factor: 0.2,
        },
        HwClass {
            name: "tpu",
            shape: Resources::cpu(8.0).with_custom("tpu", 4.0),
            price_per_hour: 8.0,
            step_factor: 0.1,
        },
    ]
}

struct Case {
    n_classes: usize,
    policy: &'static str,
    makespan_vs: f64,
    cost: f64,
    results: u64,
    cost_per_kresult: f64,
    scale_ups: u64,
}

fn run_case(n_classes: usize, hw_aware: bool, samples: usize, iters: u64) -> Case {
    let menu: Vec<HwClass> = classes().into_iter().take(n_classes).collect();
    let mut factors = ShapeFactors::new();
    for c in &menu {
        factors = factors.rule("train", &tune::ray::shape_key(&c.shape), c.step_factor);
    }
    let mut spec = ExperimentSpec::named(if hw_aware { "hw-aware" } else { "hw-blind" });
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.seed = 42;
    spec.resources_per_trial = Resources::cpu(1.0);
    spec.hw_aware = hw_aware;
    let policy = AutoscalePolicy {
        node_template: menu[0].shape.clone(),
        templates: menu
            .iter()
            .map(|c| NodeTemplate { shape: c.shape.clone(), price_per_hour: c.price_per_hour })
            .collect(),
        min_nodes: 1,
        max_nodes: 6,
        scale_up_after: 2,
        scale_down_after: 1_000_000,
        scale_down_util: 0.0,
    };
    let res = run_experiments(
        spec,
        SpaceBuilder::new()
            .loguniform("lr", 1e-4, 1.0)
            .constant("workload", ParamValue::Str("train".into()))
            .build(),
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::heterogeneous_priced(vec![(
                menu[0].shape.clone(),
                menu[0].price_per_hour,
            )]),
            exec: ExecMode::Sim,
            autoscale: Some(policy),
            shape_factors: Some(factors),
            ..Default::default()
        },
    );
    assert!(res.infeasible.is_none(), "bench scenario must be feasible");
    assert_eq!(res.trials.len(), samples, "every trial must run");
    let results = res.stats.results.max(1);
    Case {
        n_classes,
        policy: if hw_aware { "cost-aware" } else { "cost-blind" },
        makespan_vs: res.duration_s,
        cost: res.stats.cost_accrued,
        results,
        cost_per_kresult: res.stats.cost_accrued * 1000.0 / results as f64,
        scale_ups: res.stats.scale_ups,
    }
}

fn main() {
    let fast = std::env::var("TUNE_BENCH_FAST").is_ok();
    let (samples, iters) = if fast { (24, 10) } else { (96, 40) };
    println!(
        "== hw-aware scheduling: {samples} trials x {iters} iters, up to 6 nodes{} ==",
        if fast { " [FAST]" } else { "" }
    );
    println!(
        "{:>8} {:>11} {:>14} {:>10} {:>9} {:>13} {:>9}",
        "classes", "policy", "makespan(vs)", "cost($)", "results", "$/1k results", "scaleups"
    );
    let mut cases = Vec::new();
    for n_classes in [2usize, 4] {
        for hw_aware in [false, true] {
            let c = run_case(n_classes, hw_aware, samples, iters);
            println!(
                "{:>8} {:>11} {:>14.1} {:>10.4} {:>9} {:>13.4} {:>9}",
                c.n_classes, c.policy, c.makespan_vs, c.cost, c.results, c.cost_per_kresult,
                c.scale_ups
            );
            cases.push(c);
        }
    }
    let json = Json::obj(vec![
        ("bench", Json::Str("hw_sched".into())),
        ("mode", Json::Str(if fast { "fast" } else { "full" }.into())),
        ("samples", Json::Num(samples as f64)),
        ("iters", Json::Num(iters as f64)),
        (
            "cases",
            Json::Arr(
                cases
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("classes", Json::Num(c.n_classes as f64)),
                            ("policy", Json::Str(c.policy.into())),
                            ("makespan_vs", Json::Num(c.makespan_vs)),
                            ("cost", Json::Num(c.cost)),
                            ("results", Json::Num(c.results as f64)),
                            ("cost_per_kresult", Json::Num(c.cost_per_kresult)),
                            ("scale_ups", Json::Num(c.scale_ups as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write("BENCH_hw_sched.json", json.to_string()) {
        Ok(()) => println!("\nwrote BENCH_hw_sched.json"),
        Err(e) => eprintln!("\ncould not write BENCH_hw_sched.json: {e}"),
    }
}
