//! C3 bench: scaling with cluster size (§4.3.1 / §5 claims).
//!
//! (a) 512 short trials on 1..64 simulated nodes: virtual makespan must
//!     shrink near-linearly; the coordinator's wall time stays flat.
//! (b) two-level vs centralized placement microbench: local-first
//!     placement is O(1) per decision vs O(#nodes) for the central
//!     least-loaded scan — the paper's "avoids any central bottleneck".
//!
//! Run: `cargo bench --bench scaling`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, ParamValue, RunOptions, SchedulerKind,
    SearchKind,
};
use tune::ray::{Cluster, Resources, TwoLevelScheduler};
use tune::trainable::factory;
use tune::trainable::synthetic::ConstTrainable;
use tune::util::bench;

fn run_cluster(nodes: usize) -> (f64, f64, u64) {
    let mut spec = ExperimentSpec::named("scaling");
    spec.metric = "iters".into();
    spec.mode = Mode::Max;
    spec.num_samples = 512;
    spec.max_iterations_per_trial = 4;
    let space = SpaceBuilder::new().constant("step_cost", ParamValue::F64(1.0)).build();
    let t0 = std::time::Instant::now();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(ConstTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(nodes, Resources::cpu(4.0)),
            ..Default::default()
        },
    );
    (res.duration_s, t0.elapsed().as_secs_f64(), res.placement.spilled)
}

fn main() {
    println!("== C3(a): 512 trials x 4 iters, 4 cpus/node ==");
    println!(
        "{:>6} {:>14} {:>10} {:>12} {:>10}",
        "nodes", "makespan(vs)", "speedup", "wall(s)", "spilled"
    );
    let base = run_cluster(1);
    println!("{:>6} {:>14.0} {:>10.1} {:>12.3} {:>10}", 1, base.0, 1.0, base.1, base.2);
    for nodes in [2, 4, 8, 16, 32, 64] {
        let (makespan, wall, spilled) = run_cluster(nodes);
        println!(
            "{:>6} {:>14.0} {:>10.1} {:>12.3} {:>10}",
            nodes,
            makespan,
            base.0 / makespan,
            wall,
            spilled
        );
    }

    println!("\n== C3(c): wall-clock executors, 256 live trials (M >> N pool) ==");
    println!("{:>26} {:>12} {:>16}", "executor", "wall(s)", "results/sec");
    let wall_run = |exec: ExecMode| -> (f64, f64) {
        let mut spec = ExperimentSpec::named("pool-scaling");
        spec.metric = "iters".into();
        spec.mode = Mode::Max;
        spec.num_samples = 256;
        spec.max_iterations_per_trial = 8;
        let space = SpaceBuilder::new().constant("step_cost", ParamValue::F64(1.0)).build();
        let t0 = std::time::Instant::now();
        let res = run_experiments(
            spec,
            space,
            SchedulerKind::Fifo,
            SearchKind::Random,
            factory(|c, s| Box::new(ConstTrainable::new(c, s))),
            RunOptions {
                // Enough capacity that all 256 trials are live at once:
                // the executor, not the cluster, is the bottleneck.
                cluster: Cluster::uniform(8, Resources::cpu(32.0)),
                exec,
                ..Default::default()
            },
        );
        let wall = t0.elapsed().as_secs_f64();
        (wall, res.stats.results as f64 / wall)
    };
    for (name, exec) in [
        ("threads (256 threads)", ExecMode::Threads),
        ("pool (1 worker)", ExecMode::Pool { workers: 1 }),
        ("pool (2 workers)", ExecMode::Pool { workers: 2 }),
        ("pool (4 workers)", ExecMode::Pool { workers: 4 }),
        ("pool (8 workers)", ExecMode::Pool { workers: 8 }),
        ("pool (16 workers)", ExecMode::Pool { workers: 16 }),
    ] {
        let (wall, rps) = wall_run(exec);
        println!("{name:>26} {wall:>12.3} {rps:>16.0}");
    }

    println!("\n== C3(b): placement decision latency, two-level vs centralized ==");
    bench::header();
    for nodes in [4usize, 64, 512] {
        // Fill the cluster half full, then time placements into the
        // remaining capacity (steady-state decision cost).
        let demand = Resources::cpu(1.0);
        bench::bench_n(&format!("two_level/{nodes}_nodes"), 10, 100, || {
            let mut cluster = Cluster::uniform(nodes, Resources::cpu(8.0));
            let mut placer = TwoLevelScheduler::new();
            for _ in 0..nodes * 8 {
                if placer.place(&mut cluster, 0, &demand).is_none() {
                    break;
                }
            }
            std::hint::black_box(placer.stats.total());
        });
        bench::bench_n(&format!("centralized/{nodes}_nodes"), 10, 100, || {
            let mut cluster = Cluster::uniform(nodes, Resources::cpu(8.0));
            let mut placer = TwoLevelScheduler::new();
            for _ in 0..nodes * 8 {
                if placer.place_centralized(&mut cluster, 0, &demand).is_none() {
                    break;
                }
            }
            std::hint::black_box(placer.stats.total());
        });
    }
    println!("\n(expected shape: two-level stays near-flat per placement; centralized grows with node count)");
}
