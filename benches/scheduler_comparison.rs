//! C1 bench: regenerate the scheduler-comparison table (quality + budget
//! per scheduler at matched trial count) and time the full experiment
//! loop per scheduler — the cost of the coordinator itself, since trial
//! compute is virtual.
//!
//! Run: `cargo bench --bench scheduler_comparison`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;
use tune::util::bench;

const SAMPLES: usize = 64;
const MAX_T: u64 = 81;

fn run_one(kind: &SchedulerKind, seed: u64) -> tune::coordinator::ExperimentResult {
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();
    let mut spec = ExperimentSpec::named("bench");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = SAMPLES;
    spec.max_iterations_per_trial = MAX_T;
    spec.seed = seed;
    run_experiments(
        spec,
        space,
        kind.clone(),
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(4, Resources::cpu(8.0)),
            ..Default::default()
        },
    )
}

fn main() {
    let kinds: Vec<(&str, SchedulerKind)> = vec![
        ("fifo", SchedulerKind::Fifo),
        ("median_stopping", SchedulerKind::MedianStopping { grace_period: 8, min_samples: 3 }),
        ("asha", SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: MAX_T }),
        ("hyperband", SchedulerKind::HyperBand { max_t: MAX_T, eta: 3.0 }),
    ];

    println!("== C1 table: {SAMPLES} trials, max_t={MAX_T} (virtual time) ==");
    println!(
        "{:<18} {:>10} {:>12} {:>10} {:>10} {:>14}",
        "scheduler", "best acc", "budget(s)", "stopped", "results", "decision ns/res"
    );
    let mut fifo_budget = 0.0;
    for (name, kind) in &kinds {
        let res = run_one(kind, 7);
        if *name == "fifo" {
            fifo_budget = res.budget_used_s;
        }
        println!(
            "{:<18} {:>10.4} {:>12.0} {:>10} {:>10} {:>14.0}",
            name,
            res.best_metric().unwrap_or(0.0),
            res.budget_used_s,
            res.stats.stopped_early,
            res.stats.results,
            res.stats.decision_ns as f64 / res.stats.results.max(1) as f64,
        );
    }
    println!("(fifo budget reference: {fifo_budget:.0}s)\n");

    println!("== wall time of the full coordinator loop per scheduler ==");
    bench::header();
    for (name, kind) in &kinds {
        let mut seed = 0;
        bench::bench_n(&format!("experiment/{name}"), 1, 10, || {
            seed += 1;
            let r = run_one(kind, seed);
            std::hint::black_box(r.stats.results);
        });
    }
}
