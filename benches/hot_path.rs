//! C7 bench: the allocation-lean result hot path — end-to-end
//! results/sec through the full coordinator loop (sim executor, virtual
//! time) plus per-result decision/handling latency for every scheduler,
//! at 64 and 1024 trials.
//!
//! Run: `cargo bench --bench hot_path`
//!
//! `TUNE_BENCH_FAST=1` shrinks per-trial iteration counts so CI can
//! smoke the binary in seconds; the emitted `BENCH_hot_path.json`
//! records which mode produced the numbers.

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;
use tune::util::json::Json;

struct Case {
    scheduler: &'static str,
    trials: usize,
    results: u64,
    results_per_sec: f64,
    decision_ns_per_result: f64,
    handling_ns_per_result: f64,
}

fn scheduler_kind(name: &str, iters: u64) -> SchedulerKind {
    match name {
        "fifo" => SchedulerKind::Fifo,
        "asha" => SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: iters },
        "median" => {
            SchedulerKind::MedianStopping { grace_period: iters / 10 + 1, min_samples: 3 }
        }
        "hyperband" => SchedulerKind::HyperBand { max_t: iters, eta: 3.0 },
        other => unreachable!("{other}"),
    }
}

fn run_case(name: &'static str, samples: usize, iters: u64) -> Case {
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();
    let mut spec = ExperimentSpec::named("hot-path");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    let t0 = std::time::Instant::now();
    let res = run_experiments(
        spec,
        space,
        scheduler_kind(name, iters),
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(8, Resources::cpu(16.0)),
            ..Default::default()
        },
    );
    let wall = t0.elapsed().as_secs_f64();
    let n = res.stats.results.max(1);
    Case {
        scheduler: name,
        trials: samples,
        results: res.stats.results,
        results_per_sec: res.stats.results as f64 / wall,
        decision_ns_per_result: res.stats.decision_ns as f64 / n as f64,
        handling_ns_per_result: res.stats.handling_ns as f64 / n as f64,
    }
}

fn main() {
    let fast = std::env::var("TUNE_BENCH_FAST").is_ok();
    let iters = if fast { 9 } else { 81 };
    println!(
        "== result hot path: full coordinator loop (sim, virtual time), {} iters/trial{} ==",
        iters,
        if fast { " [FAST]" } else { "" },
    );
    println!(
        "{:<12} {:>7} {:>10} {:>14} {:>14} {:>14}",
        "scheduler", "trials", "results", "results/sec", "decision ns", "handling ns"
    );
    println!("{}", "-".repeat(76));
    let mut cases = Vec::new();
    for name in ["fifo", "asha", "median", "hyperband"] {
        for samples in [64usize, 1024] {
            let c = run_case(name, samples, iters);
            println!(
                "{:<12} {:>7} {:>10} {:>14.0} {:>14.0} {:>14.0}",
                c.scheduler,
                c.trials,
                c.results,
                c.results_per_sec,
                c.decision_ns_per_result,
                c.handling_ns_per_result
            );
            cases.push(c);
        }
    }

    // Machine-readable record for CI artifacts / EXPERIMENTS.md updates.
    let json = Json::obj(vec![
        ("bench", Json::Str("hot_path".into())),
        ("fast_mode", Json::Bool(fast)),
        ("iters_per_trial", Json::Num(iters as f64)),
        (
            "cases",
            Json::Arr(
                cases
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("scheduler", Json::Str(c.scheduler.into())),
                            ("trials", Json::Num(c.trials as f64)),
                            ("results", Json::Num(c.results as f64)),
                            ("results_per_sec", Json::Num(c.results_per_sec)),
                            ("decision_ns_per_result", Json::Num(c.decision_ns_per_result)),
                            ("handling_ns_per_result", Json::Num(c.handling_ns_per_result)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write("BENCH_hot_path.json", json.to_string()) {
        Ok(()) => println!("\nwrote BENCH_hot_path.json"),
        Err(e) => eprintln!("\ncould not write BENCH_hot_path.json: {e}"),
    }
}
