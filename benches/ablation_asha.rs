//! Ablation bench: ASHA's two design knobs — reduction factor eta and
//! grace period — trading terminal quality against training budget.
//! (The design-choice ablation DESIGN.md calls out: aggressive halving
//! saves budget but can cull slow starters; the grace period is the
//! guard.) The curve workload has crossing learning curves, so small
//! grace periods visibly cost accuracy at high eta.
//!
//! Run: `cargo bench --bench ablation_asha`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;

const SAMPLES: usize = 64;
const MAX_T: u64 = 81;
const SEEDS: [u64; 3] = [11, 12, 13];

fn run(grace: u64, eta: f64, seed: u64) -> (f64, f64) {
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();
    let mut spec = ExperimentSpec::named("ablation");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = SAMPLES;
    spec.max_iterations_per_trial = MAX_T;
    spec.seed = seed;
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Asha { grace_period: grace, reduction_factor: eta, max_t: MAX_T },
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(4, Resources::cpu(8.0)),
            ..Default::default()
        },
    );
    (res.best_metric().unwrap_or(0.0), res.budget_used_s)
}

fn main() {
    println!(
        "ASHA ablation: {} trials, max_t={}, mean of {} seeds\n",
        SAMPLES,
        MAX_T,
        SEEDS.len()
    );
    println!(
        "{:>6} {:>6} {:>12} {:>14} {:>12}",
        "eta", "grace", "best acc", "budget(s)", "acc/1k-s"
    );
    println!("{}", "-".repeat(56));
    for eta in [2.0, 3.0, 4.0] {
        for grace in [1u64, 3, 9] {
            let mut acc = 0.0;
            let mut budget = 0.0;
            for seed in SEEDS {
                let (a, b) = run(grace, eta, seed);
                acc += a;
                budget += b;
            }
            let n = SEEDS.len() as f64;
            acc /= n;
            budget /= n;
            println!(
                "{eta:>6.1} {grace:>6} {acc:>12.4} {budget:>14.0} {:>12.3}",
                acc / (budget / 1000.0)
            );
        }
    }
    println!("\n(expected shape: higher eta / lower grace => less budget, slightly lower");
    println!(" terminal accuracy; grace>=3 recovers most of the quality at small cost)");
}
