//! Serve control-plane load bench: QPS and tail latency of a live
//! socket server at 1 / 4 / 16 hub shards over ONE shared worker fleet.
//!
//! Each case boots an in-process `serve` on an ephemeral TCP port and
//! drives it the way real clients would: M persistent connections fire
//! a burst of unique-name submissions, then churn `status` requests,
//! while one well-behaved `watch` stream stays attached throughout; the
//! case ends with a stop-and-drain that must complete every admitted
//! experiment. Reported per case: submissions/sec, status QPS, p99
//! latency for both verbs, bytes moved per request and drain time.
//!
//! What to look for: submission throughput should grow with shards —
//! admission serializes on a shard's command loop, so hashing
//! experiments across N shards removes the single-hub funnel — while
//! status QPS stays flat-ish (it reads per-shard cached cells and never
//! touches a shard thread).
//!
//! `TUNE_BENCH_FAST=1` shrinks connection and request counts so CI can
//! smoke the binary in seconds; the emitted `BENCH_serve_qps.json`
//! records which mode produced the numbers.
//!
//! Run: `cargo bench --bench serve_qps`

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

use tune::net::{
    serve, Client, ListenAddr, ServeOptions, ShardedHub, ShardedHubOptions, WorkloadResolver,
};
use tune::trainable::factory;
use tune::trainable::synthetic::ConstTrainable;
use tune::util::json::Json;

const WORKERS: usize = 4;

fn const_resolver() -> WorkloadResolver {
    Arc::new(|w: &str| {
        if w == "const" {
            Ok(factory(|c, s| Box::new(ConstTrainable::new(c, s))))
        } else {
            Err(format!("unknown workload {w:?}"))
        }
    })
}

/// A tiny constant-workload experiment (2 trials x 2 iters): the bench
/// measures the control plane, not the training loop.
fn spec_text(name: &str, seed: u64) -> String {
    format!(
        r#"{{
            "name": "{name}", "metric": "iters", "mode": "max",
            "num_samples": 2, "max_iterations_per_trial": 2, "seed": {seed},
            "workload": "const", "scheduler": "fifo", "search": "random",
            "space": {{"step_cost": {{"uniform": [1.0, 1.0]}}}},
            "cluster": {{"nodes": 1, "cpus_per_node": 8}}
        }}"#
    )
}

/// p99 of a latency sample, in milliseconds (sorts in place).
fn p99_ms(lat: &mut [u128]) -> f64 {
    if lat.is_empty() {
        return 0.0;
    }
    lat.sort_unstable();
    let idx = ((lat.len() as f64 * 0.99).ceil() as usize).saturating_sub(1);
    lat[idx.min(lat.len() - 1)] as f64 / 1e6
}

struct Case {
    shards: usize,
    submit_qps: f64,
    submit_p99_ms: f64,
    status_qps: f64,
    status_p99_ms: f64,
    bytes_per_req: f64,
    watch_events: usize,
    drain_s: f64,
}

fn run_case(shards: usize, conns: usize, submits: usize, statuses: usize) -> Case {
    let hub = ShardedHub::new(ShardedHubOptions { shards, workers: WORKERS, ..Default::default() });
    let addr = ListenAddr::parse("127.0.0.1:0").expect("parse addr");
    let handle = serve(&addr, hub, const_resolver(), ServeOptions::default()).expect("serve");
    let addr = handle.addr().clone();

    // One live, acking watch stream for the whole case: realistic
    // status-delta traffic that must never be shed.
    let watch_events = Arc::new(AtomicUsize::new(0));
    let we = Arc::clone(&watch_events);
    let waddr = addr.clone();
    let watcher = std::thread::spawn(move || {
        let c = Client::connect(&waddr).expect("watch conn");
        c.watch(|_| {
            we.fetch_add(1, Ordering::Relaxed);
            true
        })
        .expect("watch stream");
    });

    // Phase 1 — submit burst: M persistent conns x B unique names.
    let t0 = Instant::now();
    let joins: Vec<_> = (0..conns)
        .map(|ci| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("submit conn");
                let mut lat = Vec::with_capacity(submits);
                for i in 0..submits {
                    let text = spec_text(&format!("load-{ci}-{i}"), (ci * 1009 + i) as u64);
                    let t = Instant::now();
                    c.submit_spec_text(&text).expect("submit");
                    lat.push(t.elapsed().as_nanos());
                }
                (lat, c.bytes_moved())
            })
        })
        .collect();
    let mut submit_lat = Vec::new();
    let mut bytes = 0u64;
    for j in joins {
        let (lat, moved) = j.join().expect("submit thread");
        submit_lat.extend(lat);
        bytes += moved;
    }
    let submit_wall = t0.elapsed().as_secs_f64();

    // Phase 2 — status churn on fresh persistent conns while the
    // experiments run.
    let t0 = Instant::now();
    let joins: Vec<_> = (0..conns)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).expect("status conn");
                let mut lat = Vec::with_capacity(statuses);
                for _ in 0..statuses {
                    let t = Instant::now();
                    c.status().expect("status");
                    lat.push(t.elapsed().as_nanos());
                }
                (lat, c.bytes_moved())
            })
        })
        .collect();
    let mut status_lat = Vec::new();
    for j in joins {
        let (lat, moved) = j.join().expect("status thread");
        status_lat.extend(lat);
        bytes += moved;
    }
    let status_wall = t0.elapsed().as_secs_f64();

    // Phase 3 — stop and drain: every admitted experiment completes.
    let t0 = Instant::now();
    handle.shutdown(true);
    let results = handle.join();
    let drain_s = t0.elapsed().as_secs_f64();
    assert_eq!(results.len(), conns * submits, "drain lost experiments");
    watcher.join().expect("watcher thread");

    let reqs = (submit_lat.len() + status_lat.len()) as f64;
    Case {
        shards,
        submit_qps: submit_lat.len() as f64 / submit_wall,
        submit_p99_ms: p99_ms(&mut submit_lat),
        status_qps: status_lat.len() as f64 / status_wall,
        status_p99_ms: p99_ms(&mut status_lat),
        bytes_per_req: bytes as f64 / reqs,
        watch_events: watch_events.load(Ordering::Relaxed),
        drain_s,
    }
}

fn main() {
    let fast = std::env::var("TUNE_BENCH_FAST").is_ok();
    let (conns, submits, statuses) = if fast { (2, 4, 16) } else { (8, 8, 64) };
    println!(
        "== serve QPS: {conns} conns x ({submits} submits + {statuses} status reqs), \
         {WORKERS} workers{} ==",
        if fast { " [FAST]" } else { "" }
    );
    println!(
        "{:>7} {:>12} {:>12} {:>11} {:>11} {:>10} {:>7} {:>9}",
        "shards", "submit/s", "sub p99 ms", "status/s", "st p99 ms", "bytes/req", "watch", "drain s"
    );
    let mut cases = Vec::new();
    for shards in [1usize, 4, 16] {
        let c = run_case(shards, conns, submits, statuses);
        println!(
            "{:>7} {:>12.1} {:>12.3} {:>11.1} {:>11.3} {:>10.0} {:>7} {:>9.2}",
            c.shards,
            c.submit_qps,
            c.submit_p99_ms,
            c.status_qps,
            c.status_p99_ms,
            c.bytes_per_req,
            c.watch_events,
            c.drain_s
        );
        cases.push(c);
    }
    let json = Json::obj(vec![
        ("bench", Json::Str("serve_qps".into())),
        ("mode", Json::Str(if fast { "fast" } else { "full" }.into())),
        ("workers", Json::Num(WORKERS as f64)),
        ("conns", Json::Num(conns as f64)),
        ("submits_per_conn", Json::Num(submits as f64)),
        ("statuses_per_conn", Json::Num(statuses as f64)),
        (
            "cases",
            Json::Arr(
                cases
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("shards", Json::Num(c.shards as f64)),
                            ("submit_qps", Json::Num(c.submit_qps)),
                            ("submit_p99_ms", Json::Num(c.submit_p99_ms)),
                            ("status_qps", Json::Num(c.status_qps)),
                            ("status_p99_ms", Json::Num(c.status_p99_ms)),
                            ("bytes_per_req", Json::Num(c.bytes_per_req)),
                            ("watch_events", Json::Num(c.watch_events as f64)),
                            ("drain_s", Json::Num(c.drain_s)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write("BENCH_serve_qps.json", json.to_string()) {
        Ok(()) => println!("\nwrote BENCH_serve_qps.json"),
        Err(e) => eprintln!("\ncould not write BENCH_serve_qps.json: {e}"),
    }
}
