//! C4 bench: coordinator overhead — the cost Tune adds per intermediate
//! result on top of raw trial compute. Measures end-to-end results/sec
//! through the full runner (admission, scheduler callback, decision
//! application, logging fan-out) with near-zero-cost trainables, plus
//! the checkpoint path (save/restore round-trips through the store).
//!
//! Run: `cargo bench --bench runner_overhead`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, ParamValue, RunOptions, SchedulerKind,
    SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::{ConstTrainable, CurveTrainable};
use tune::util::bench;

fn throughput(kind: SchedulerKind, samples: usize, iters: u64, checkpoint_freq: u64) -> f64 {
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .constant("step_cost", ParamValue::F64(1.0))
        .build();
    let mut spec = ExperimentSpec::named("overhead");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    spec.checkpoint_freq = checkpoint_freq;
    let t0 = std::time::Instant::now();
    let res = run_experiments(
        spec,
        space,
        kind,
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(4, Resources::cpu(8.0)),
            ..Default::default()
        },
    );
    res.stats.results as f64 / t0.elapsed().as_secs_f64()
}

/// Results/sec of a FIFO experiment with near-zero-cost trainables on a
/// given executor — isolates the substrate's dispatch overhead.
fn executor_throughput(exec: ExecMode, samples: usize, iters: u64) -> f64 {
    let space = SpaceBuilder::new().constant("step_cost", ParamValue::F64(1.0)).build();
    let mut spec = ExperimentSpec::named("exec-overhead");
    spec.metric = "iters".into();
    spec.mode = Mode::Max;
    spec.num_samples = samples;
    spec.max_iterations_per_trial = iters;
    let t0 = std::time::Instant::now();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Fifo,
        SearchKind::Random,
        factory(|c, s| Box::new(ConstTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(4, Resources::cpu(64.0)),
            exec,
            ..Default::default()
        },
    );
    res.stats.results as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    println!("== executor dispatch overhead: 128 trials x 25 iters, fifo, results/sec ==");
    println!("{:<34} {:>16}", "executor", "results/sec");
    for (name, exec) in [
        ("sim (virtual clock)", ExecMode::Sim),
        ("threads (1 thread/trial)", ExecMode::Threads),
        ("pool (4 workers)", ExecMode::Pool { workers: 4 }),
        ("pool (16 workers)", ExecMode::Pool { workers: 16 }),
    ] {
        let rps = executor_throughput(exec, 128, 25);
        println!("{name:<34} {rps:>16.0}");
    }

    println!("\n== runner throughput: intermediate results/sec through the full loop ==");
    println!("{:<34} {:>16}", "configuration", "results/sec");
    for (name, kind) in [
        ("fifo", SchedulerKind::Fifo),
        ("asha", SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 81 }),
        ("median_stopping", SchedulerKind::MedianStopping { grace_period: 8, min_samples: 3 }),
        ("hyperband", SchedulerKind::HyperBand { max_t: 81, eta: 3.0 }),
    ] {
        let rps = throughput(kind, 64, 81, 0);
        println!("{name:<34} {rps:>16.0}");
    }
    let rps = throughput(SchedulerKind::Fifo, 64, 81, 5);
    println!("{:<34} {:>16.0}", "fifo + checkpoint every 5 iters", rps);

    println!("\n== hot-path micro-benches ==");
    bench::header();

    // Checkpoint store round-trip at MLP state size (~46 KB).
    let blob = vec![0u8; 11_566 * 4];
    bench::bench_n("checkpoint/save+get 46KB", 100, 1000, || {
        let mut store = tune::checkpoint::CheckpointStore::new();
        let id = store.save(1, 1, blob.clone());
        std::hint::black_box(store.get(id).map(|b| b.len()));
    });

    // Trainable step dispatch through the boxed trait.
    let f = factory(|c, s| Box::new(ConstTrainable::new(c, s)));
    let mut t = f(&Default::default(), 0);
    bench::bench_n("trainable/boxed step", 1000, 10_000, || {
        std::hint::black_box(t.step().unwrap().metrics.len());
    });

    // Whole small experiment (admission + events + teardown).
    bench::bench_n("experiment/16x20 fifo end-to-end", 2, 30, || {
        let space = SpaceBuilder::new().loguniform("lr", 1e-4, 1.0).build();
        let mut spec = ExperimentSpec::named("micro");
        spec.metric = "accuracy".into();
        spec.mode = Mode::Max;
        spec.num_samples = 16;
        spec.max_iterations_per_trial = 20;
        let res = run_experiments(
            spec,
            space,
            SchedulerKind::Fifo,
            SearchKind::Random,
            factory(|c, s| Box::new(CurveTrainable::new(c, s))),
            RunOptions::default(),
        );
        std::hint::black_box(res.stats.results);
    });
}
