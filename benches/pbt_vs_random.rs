//! C2 bench: PBT vs static random search on the non-stationary
//! objective (optimal lr decays over time), across seeds — regenerates
//! the PBT-paper-shaped result that the paper's §4.2 claim 3 (clone
//! parameters of promising trials mid-training) exists to enable.
//!
//! Run: `cargo bench --bench pbt_vs_random`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::NonStationaryTrainable;
use tune::util::bench;

fn run(kind: SchedulerKind, seed: u64) -> tune::coordinator::ExperimentResult {
    let space = SpaceBuilder::new().loguniform("lr", 1e-4, 0.5).build();
    let mut spec = ExperimentSpec::named("c2");
    spec.metric = "score".into();
    spec.mode = Mode::Max;
    spec.num_samples = 16;
    spec.max_iterations_per_trial = 160;
    spec.seed = seed;
    run_experiments(
        spec,
        space,
        kind,
        SearchKind::Random,
        factory(|c, s| Box::new(NonStationaryTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(2, Resources::cpu(8.0)),
            ..Default::default()
        },
    )
}

fn main() {
    let space = SpaceBuilder::new().loguniform("lr", 1e-4, 0.5).build();
    println!("== C2 table: population 16, 160 iters, perturb every 10 ==");
    println!(
        "{:>6} {:>12} {:>12} {:>8} {:>10} {:>10}",
        "seed", "pbt score", "rand score", "ratio", "exploits", "mutated"
    );
    let mut ratios = Vec::new();
    for seed in [1u64, 2, 3, 4, 5] {
        let pbt = run(
            SchedulerKind::Pbt { perturbation_interval: 10, space: space.clone() },
            seed,
        );
        let rnd = run(SchedulerKind::Fifo, seed);
        let ratio = pbt.best_metric().unwrap() / rnd.best_metric().unwrap();
        ratios.push(ratio);
        println!(
            "{:>6} {:>12.2} {:>12.2} {:>8.2} {:>10} {:>10}",
            seed,
            pbt.best_metric().unwrap(),
            rnd.best_metric().unwrap(),
            ratio,
            pbt.stats.exploits,
            pbt.trials.values().filter(|t| t.mutations > 0).count(),
        );
    }
    let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
    println!("\nmean PBT advantage: {mean:.2}x (paper-shape: PBT > static on non-stationary objectives)");

    println!("\n== wall time ==");
    bench::header();
    let mut seed = 10;
    bench::bench_n("pbt/16x160 experiment", 1, 10, || {
        seed += 1;
        std::hint::black_box(
            run(SchedulerKind::Pbt { perturbation_interval: 10, space: space.clone() }, seed)
                .stats
                .exploits,
        );
    });
}
