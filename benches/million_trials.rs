//! C8 bench: the million-trial coordinator — end-to-end throughput of
//! the indexed per-event hot loops at trial counts two orders of
//! magnitude past the other benches. 100k trials through FIFO and ASHA
//! on the sim executor (virtual time, single thread: pure coordinator
//! cost), plus a 10k-trial smoke on the real thread-pool executor.
//!
//! Run: `cargo bench --bench million_trials`
//!
//! Reported per case: results/sec, events/sec (launches + results +
//! terminals through the event loop), and peak resident heap per trial
//! (a counting allocator watches the whole process, so the number is a
//! conservative upper bound on trial-table bytes/trial).
//!
//! `TUNE_BENCH_FAST=1` shrinks trial counts so CI can smoke the binary
//! in seconds; the emitted `BENCH_million_trials.json` records which
//! mode produced the numbers.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;
use tune::util::json::Json;

/// Tracks live heap bytes and the high-water mark since the last reset.
struct PeakAlloc;

static LIVE: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

fn note_alloc(size: usize) {
    let now = LIVE.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
    PEAK.fetch_max(now, Ordering::Relaxed);
}

// SAFETY: defers entirely to `System`; counters are relaxed atomics.
unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note_alloc(layout.size());
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        LIVE.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        note_alloc(new_size);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: PeakAlloc = PeakAlloc;

/// Restart the high-water mark at the current live size, so each case
/// measures only its own growth above the steady baseline.
fn reset_peak() -> u64 {
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    base
}

struct Case {
    label: &'static str,
    exec: &'static str,
    trials: usize,
    results: u64,
    wall_s: f64,
    results_per_sec: f64,
    events_per_sec: f64,
    peak_bytes_per_trial: f64,
}

fn run_case(label: &'static str, kind: SchedulerKind, exec: ExecMode, trials: usize) -> Case {
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();
    let mut spec = ExperimentSpec::named("million-trials");
    spec.metric = "accuracy".into();
    spec.mode = Mode::Max;
    spec.num_samples = trials;
    spec.max_iterations_per_trial = 3;
    let exec_name = match exec {
        ExecMode::Sim => "sim",
        ExecMode::Pool { .. } => "pool",
        ExecMode::Threads => "threads",
    };
    let base = reset_peak();
    let t0 = std::time::Instant::now();
    let res = run_experiments(
        spec,
        space,
        kind,
        SearchKind::Random,
        factory(|c, s| Box::new(CurveTrainable::new(c, s))),
        RunOptions {
            cluster: Cluster::uniform(32, Resources::cpu(64.0)),
            exec,
            ..Default::default()
        },
    );
    let wall = t0.elapsed().as_secs_f64().max(1e-9);
    let peak = PEAK.load(Ordering::Relaxed).saturating_sub(base);
    assert_eq!(res.trials.len(), trials, "{label}: not every trial ran");
    // Events through the loop: one launch per placement, one result per
    // step, one terminal per trial (early stops make this approximate
    // from below — a conservative denominator).
    let events = res.stats.results + res.placement.total() + trials as u64;
    Case {
        label,
        exec: exec_name,
        trials,
        results: res.stats.results,
        wall_s: wall,
        results_per_sec: res.stats.results as f64 / wall,
        events_per_sec: events as f64 / wall,
        peak_bytes_per_trial: peak as f64 / trials as f64,
    }
}

fn main() {
    let fast = std::env::var("TUNE_BENCH_FAST").is_ok();
    let (big, smoke) = if fast { (2_000, 500) } else { (100_000, 10_000) };
    println!(
        "== million-trial coordinator: indexed per-event hot loops, {} sim trials{} ==",
        big,
        if fast { " [FAST]" } else { "" },
    );
    println!(
        "{:<14} {:>6} {:>8} {:>9} {:>8} {:>13} {:>12} {:>12}",
        "case", "exec", "trials", "results", "wall s", "results/sec", "events/sec", "peak B/trial"
    );
    println!("{}", "-".repeat(88));
    let mut cases = Vec::new();
    let runs: Vec<(&'static str, SchedulerKind, ExecMode, usize)> = vec![
        ("fifo-sim", SchedulerKind::Fifo, ExecMode::Sim, big),
        (
            "asha-sim",
            SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: 3 },
            ExecMode::Sim,
            big,
        ),
        ("fifo-pool", SchedulerKind::Fifo, ExecMode::Pool { workers: 8 }, smoke),
    ];
    for (label, kind, exec, trials) in runs {
        let c = run_case(label, kind, exec, trials);
        println!(
            "{:<14} {:>6} {:>8} {:>9} {:>8.2} {:>13.0} {:>12.0} {:>12.0}",
            c.label,
            c.exec,
            c.trials,
            c.results,
            c.wall_s,
            c.results_per_sec,
            c.events_per_sec,
            c.peak_bytes_per_trial
        );
        cases.push(c);
    }

    // Machine-readable record for CI artifacts / EXPERIMENTS.md updates.
    let json = Json::obj(vec![
        ("bench", Json::Str("million_trials".into())),
        ("fast_mode", Json::Bool(fast)),
        ("iters_per_trial", Json::Num(3.0)),
        (
            "cases",
            Json::Arr(
                cases
                    .iter()
                    .map(|c| {
                        Json::obj(vec![
                            ("case", Json::Str(c.label.into())),
                            ("exec", Json::Str(c.exec.into())),
                            ("trials", Json::Num(c.trials as f64)),
                            ("results", Json::Num(c.results as f64)),
                            ("wall_s", Json::Num(c.wall_s)),
                            ("results_per_sec", Json::Num(c.results_per_sec)),
                            ("events_per_sec", Json::Num(c.events_per_sec)),
                            ("peak_bytes_per_trial", Json::Num(c.peak_bytes_per_trial)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    match std::fs::write("BENCH_million_trials.json", json.to_string()) {
        Ok(()) => println!("\nwrote BENCH_million_trials.json"),
        Err(e) => eprintln!("\ncould not write BENCH_million_trials.json: {e}"),
    }
}
