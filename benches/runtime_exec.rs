//! Runtime (L1/L2) bench: latency of the AOT-compiled train step through
//! PJRT per model variant, plus the checkpoint serialize/restore path.
//! These are the numbers the L3 coordinator overhead is compared against
//! in EXPERIMENTS.md §Perf (coordinator cost must be ≪ step cost).
//!
//! Requires artifacts (`make artifacts`); exits gracefully otherwise.
//!
//! Run: `cargo bench --bench runtime_exec`

use tune::runtime::{Manifest, PjrtService};
use tune::util::bench;

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping runtime bench: run `make artifacts`");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let svc = PjrtService::spawn(dir).unwrap();

    bench::header();
    let mut session = 0u64;
    for (name, mm) in &manifest.models {
        session += 1;
        svc.open(session, name, 42).unwrap();
        // One step to trigger compilation outside the timed region.
        svc.step(session, 1, 0.05, 0.9).unwrap();

        let s = session;
        let svc2 = svc.clone();
        bench::bench_n(&format!("train_step/{name} ({}p)", mm.param_count), 3, 30, move || {
            std::hint::black_box(svc2.step(s, 1, 0.05, 0.9).unwrap().0);
        });

        let svc3 = svc.clone();
        let stats = bench::bench_n(&format!("checkpoint_save/{name}"), 3, 30, move || {
            std::hint::black_box(svc3.save(s).unwrap().len());
        });
        let state_bytes = mm.state_elements() * 4 + 16;
        println!(
            "    -> {} KB state, {:.0} MB/s serialize",
            state_bytes / 1024,
            state_bytes as f64 / stats.median_ns * 1e3
        );

        let blob = svc.save(session).unwrap();
        let svc4 = svc.clone();
        bench::bench_n(&format!("checkpoint_restore/{name}"), 3, 30, move || {
            svc4.restore(s, blob.clone()).unwrap();
        });
        svc.close(session);
    }

    // Amortization: 5 steps per report (what the trainable does).
    svc.open(999, "mlp_relu", 1).unwrap();
    svc.step(999, 1, 0.05, 0.9).unwrap();
    let svc5 = svc.clone();
    bench::bench_n("train_step/mlp_relu x5 batched", 3, 30, move || {
        std::hint::black_box(svc5.step(999, 5, 0.05, 0.9).unwrap().0);
    });
    svc.shutdown();
}
