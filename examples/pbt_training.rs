//! Population-Based Training demo (C2): a 16-trial population on the
//! non-stationary objective where the optimal learning rate decays over
//! time. PBT clones top performers' checkpoints into bottom performers
//! and perturbs their lr (exploit + explore) every 10 iterations —
//! tracking the moving optimum, which no static configuration can.
//!
//! Run: `cargo run --release --example pbt_training`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::synthetic::NonStationaryTrainable;
use tune::trainable::factory;

fn main() {
    let space = SpaceBuilder::new().loguniform("lr", 1e-4, 0.5).build();
    let mut spec = ExperimentSpec::named("pbt");
    spec.metric = "score".into();
    spec.mode = Mode::Max;
    spec.num_samples = 16;
    spec.max_iterations_per_trial = 160;
    spec.seed = 3;

    let run = |kind: SchedulerKind, name: &str| {
        let res = run_experiments(
            spec.clone(),
            space.clone(),
            kind,
            SearchKind::Random,
            factory(|c, s| Box::new(NonStationaryTrainable::new(c, s))),
            RunOptions {
                cluster: Cluster::uniform(2, Resources::cpu(8.0)),
                log_dir: Some(format!("tune_logs/pbt_demo_{name}").into()),
                ..Default::default()
            },
        );
        println!(
            "{:<22} best score {:>8.2}   exploits {:>3}   mutated trials {:>2}",
            name,
            res.best_metric().unwrap_or(0.0),
            res.stats.exploits,
            res.trials.values().filter(|t| t.mutations > 0).count(),
        );
        res
    };

    println!("non-stationary objective: lr*(t) = 0.1 * 10^(-t/40)\n");
    let pbt = run(
        SchedulerKind::Pbt { perturbation_interval: 10, space: space.clone() },
        "pbt",
    );
    let random = run(SchedulerKind::Fifo, "random_static");

    let ratio = pbt.best_metric().unwrap() / random.best_metric().unwrap();
    println!("\nPBT / static-random score ratio: {ratio:.2}x");

    // Show the winning lineage: lr mutations over time, from the logs.
    let best = pbt.best.unwrap();
    let a = tune::logger::ExperimentAnalysis::load(std::path::Path::new("tune_logs/pbt_demo_pbt"))
        .unwrap();
    if let Some(rec) = a.trials.get(&best) {
        println!("\nbest trial #{best}: lr trajectory (PBT mutations track lr*(t)):");
        let step = (rec.rows.len() / 14).max(1);
        for (iter, _, m) in rec.rows.iter().step_by(step) {
            if let Some(lr) = m.get("lr") {
                let opt = NonStationaryTrainable::optimal_lr_at(*iter, 40.0);
                println!("  iter {iter:>4}  lr {lr:>9.5}  (lr* {opt:>9.5})");
            }
        }
    }
}
