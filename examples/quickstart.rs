//! Quickstart — the paper's §4.3 minimal example, on real compute:
//!
//! ```python
//! tune.run_experiments(my_func, {
//!     "lr": tune.grid_search([0.01, 0.001, 0.0001]),
//!     "activation": tune.grid_search(["relu", "tanh"]),
//! }, scheduler=HyperBand)
//! ```
//!
//! Here `my_func` is the AOT-compiled JAX MLP (L2) with Pallas
//! fused-linear kernels (L1), trained through PJRT from the rust
//! coordinator (L3). Falls back to the synthetic curve workload when
//! artifacts are absent.
//!
//! Run: `cargo run --release --example quickstart`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::runtime::{Manifest, PjrtService};
use tune::trainable::jax_model::jax_factory;
use tune::trainable::{factory, synthetic::CurveTrainable};

fn main() {
    let space = SpaceBuilder::new()
        .grid_f64("lr", &[0.1, 0.01, 0.001]) // MLP's useful range
        .grid_str("activation", &["relu", "tanh"])
        .build();

    let mut spec = ExperimentSpec::named("quickstart");
    spec.metric = "loss".into();
    spec.mode = Mode::Min;
    spec.max_iterations_per_trial = 9; // 9 reports x 5 PJRT steps
    spec.checkpoint_freq = 3;

    let artifacts = Manifest::default_dir();
    let (fac, exec) = if artifacts.join("manifest.json").exists() {
        println!("using AOT JAX/Pallas MLP via PJRT ({artifacts:?})");
        let svc = PjrtService::spawn(artifacts).expect("spawn PJRT service");
        (jax_factory(svc, "mlp", 5), ExecMode::Threads)
    } else {
        println!("artifacts missing — falling back to synthetic curves (run `make artifacts`)");
        spec.metric = "accuracy".into();
        spec.mode = Mode::Max;
        (
            factory(|c: &tune::coordinator::Config, s: u64| {
                Box::new(CurveTrainable::new(c, s)) as Box<dyn tune::trainable::Trainable>
            }),
            ExecMode::Sim,
        )
    };

    let res = run_experiments(
        spec,
        space,
        SchedulerKind::HyperBand { max_t: 9, eta: 3.0 },
        SearchKind::Grid,
        fac,
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(4.0)),
            exec,
            progress_every: 10,
            log_dir: Some("tune_logs/quickstart".into()),
            ..Default::default()
        },
    );

    println!("\n=== quickstart: 3x2 grid under HyperBand ===");
    println!("{:<40} {:>8} {:>10} {:>12}", "config", "iters", "status", "best metric");
    for t in res.trials.values() {
        println!(
            "{:<40} {:>8} {:>10} {:>12}",
            tune::coordinator::trial::config_str(&t.config),
            t.iteration,
            format!("{:?}", t.status),
            t.best_metric.map(|m| format!("{m:.4}")).unwrap_or_else(|| "-".into()),
        );
    }
    if let Some(best) = res.best {
        println!(
            "\nbest: trial #{best} [{}] -> {:.4}",
            tune::coordinator::trial::config_str(&res.trials[&best].config),
            res.best_metric().unwrap()
        );
    }
    println!("logs: tune_logs/quickstart (try `tune analyze --log-dir tune_logs/quickstart`)");
}
