//! End-to-end model-selection driver (the E2E experiment of DESIGN.md):
//! ASHA over {lr, momentum, activation} of the transformer language
//! model — every layer of the stack composing on a real workload:
//!
//!   L1 Pallas fused-linear + attention kernels
//!     -> L2 JAX fwd/bwd/SGD-momentum train step
//!       -> AOT HLO text -> PJRT CPU executable
//!         -> L3 rust coordinator (ASHA, checkpoints, ray substrate)
//!
//! 12 trials, up to 60 reported iterations x 5 train steps = 300 PJRT
//! steps for surviving trials; ASHA culls the rest at rungs 3/9/27.
//! Loss curves land in tune_logs/e2e_transformer/ and the summary is
//! recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example e2e_transformer`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExecMode, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::logger::ExperimentAnalysis;
use tune::ray::{Cluster, Resources};
use tune::runtime::{Manifest, PjrtService};
use tune::trainable::jax_model::jax_factory;

fn main() {
    let artifacts = Manifest::default_dir();
    if !artifacts.join("manifest.json").exists() {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(1);
    }
    let manifest = Manifest::load(&artifacts).unwrap();
    let tlm = manifest.model("tlm_gelu").unwrap();
    println!(
        "transformer LM: {} params, batch {}, vocab {} (Pallas attention + fused-linear inside)",
        tlm.param_count,
        tlm.batch,
        tlm.meta.get("vocab").and_then(|v| v.as_u64()).unwrap_or(0)
    );

    let svc = PjrtService::spawn(artifacts).expect("spawn PJRT service");

    let space = SpaceBuilder::new()
        .loguniform("lr", 3e-3, 1.0)
        .uniform("momentum", 0.5, 0.99)
        .choice_str("activation", &["gelu", "relu"])
        .build();

    let mut spec = ExperimentSpec::named("e2e_transformer");
    spec.metric = "loss".into();
    spec.mode = Mode::Min;
    spec.num_samples = 12;
    spec.max_iterations_per_trial = 60; // x5 = 300 PJRT steps max
    spec.checkpoint_freq = 9;
    spec.max_concurrent = 4;
    spec.seed = 1;

    let t0 = std::time::Instant::now();
    let res = run_experiments(
        spec,
        space,
        SchedulerKind::Asha { grace_period: 3, reduction_factor: 3.0, max_t: 60 },
        SearchKind::Random,
        jax_factory(svc.clone(), "tlm", 5),
        RunOptions {
            cluster: Cluster::uniform(1, Resources::cpu(4.0)),
            exec: ExecMode::Threads,
            progress_every: 50,
            log_dir: Some("tune_logs/e2e_transformer".into()),
            ..Default::default()
        },
    );
    svc.shutdown();
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== e2e transformer model selection ===");
    println!("wall time            : {wall:.1}s");
    println!("trials               : {}", res.trials.len());
    println!(
        "completed / stopped  : {} / {}",
        res.stats.completed, res.stats.stopped_early
    );
    println!(
        "total PJRT steps     : {} (x5 per iteration)",
        res.total_iterations() * 5
    );
    println!("checkpoints/restores : {}/{}", res.stats.checkpoints, res.stats.restores);

    println!("\n{:<52} {:>6} {:>9} {:>10}", "config", "iters", "status", "final loss");
    for t in res.trials.values() {
        println!(
            "{:<52} {:>6} {:>9} {:>10}",
            tune::coordinator::trial::config_str(&t.config),
            t.iteration,
            format!("{:?}", t.status),
            t.last_result
                .as_ref()
                .and_then(|r| r.metric(&res.schema, "loss"))
                .map(|l| format!("{l:.3}"))
                .unwrap_or_else(|| "-".into()),
        );
    }

    let best = res.best.expect("a best trial");
    println!(
        "\nbest trial #{best}: loss {:.4} [{}]",
        res.best_metric().unwrap(),
        tune::coordinator::trial::config_str(&res.trials[&best].config)
    );

    // Print the winner's loss curve from the JSONL logs.
    let a = ExperimentAnalysis::load(std::path::Path::new("tune_logs/e2e_transformer")).unwrap();
    if let Some(rec) = a.trials.get(&best) {
        println!("\nbest-trial loss curve (iteration -> loss; ln(128)=4.85 init, chain entropy ln(4)=1.39):");
        let step = (rec.rows.len() / 12).max(1);
        for (iter, _, m) in rec.rows.iter().step_by(step) {
            if let Some(l) = m.get("loss") {
                let bar = "#".repeat((l * 12.0) as usize);
                println!("  iter {iter:>4}  loss {l:>7.3}  {bar}");
            }
        }
    }
    println!("\nlogs: tune_logs/e2e_transformer");
}
