//! Regenerate the paper's Table 1: lines of code per model-selection
//! algorithm implemented in Tune. Counted the same way the paper does
//! (logging/debugging lines included, test modules excluded); paper
//! numbers alongside ours for comparison.
//!
//! Run: `cargo run --release --example table1_loc`

use tune::util::loc;

fn main() {
    let rows = loc::table1(std::path::Path::new(env!("CARGO_MANIFEST_DIR")));
    loc::print_table1(&rows);
    println!();
    for r in &rows {
        println!("{:<28} <- {}", r.algorithm, r.files.join(", "));
    }
    let (p, o): (usize, usize) = rows.iter().map(|r| (r.paper_loc, r.our_loc)).fold(
        (0, 0),
        |(ap, ao), (p, o)| (ap + p, ao + o),
    );
    println!("\ntotal: paper {p} LoC, ours {o} LoC");
    println!(
        "(the paper's point: every algorithm fits in tens-to-hundreds of lines\n\
         against the narrow scheduler API — the distributed machinery lives\n\
         behind the interface, not in the algorithms)"
    );
}
