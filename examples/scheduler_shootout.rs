//! Scheduler shootout (C1, figure-equivalent): best-found accuracy as a
//! function of consumed training budget for FIFO / median stopping /
//! ASHA / HyperBand, averaged over seeds, on 96 random-search trials of
//! the synthetic curve workload. The expected *shape* (from the
//! HyperBand/ASHA papers): early-stopping schedulers reach a given
//! quality with a fraction of FIFO's budget; ASHA ~ HyperBand.
//!
//! Run: `cargo run --release --example scheduler_shootout`

use tune::coordinator::spec::SpaceBuilder;
use tune::coordinator::{
    run_experiments, ExperimentSpec, Mode, RunOptions, SchedulerKind, SearchKind,
};
use tune::ray::{Cluster, Resources};
use tune::trainable::factory;
use tune::trainable::synthetic::CurveTrainable;

const SAMPLES: usize = 96;
const MAX_T: u64 = 81;
const SEEDS: [u64; 3] = [1, 2, 3];

fn kinds(space: &tune::coordinator::spec::SearchSpace) -> Vec<(&'static str, SchedulerKind)> {
    let _ = space;
    vec![
        ("fifo", SchedulerKind::Fifo),
        ("median_stopping", SchedulerKind::MedianStopping { grace_period: 8, min_samples: 3 }),
        ("asha", SchedulerKind::Asha { grace_period: 1, reduction_factor: 3.0, max_t: MAX_T }),
        ("hyperband", SchedulerKind::HyperBand { max_t: MAX_T, eta: 3.0 }),
    ]
}

fn main() {
    let space = SpaceBuilder::new()
        .loguniform("lr", 1e-4, 1.0)
        .uniform("momentum", 0.8, 0.99)
        .build();

    println!(
        "C1 shootout: {} random trials, max_t={}, {} seeds (virtual time)\n",
        SAMPLES,
        MAX_T,
        SEEDS.len()
    );
    println!(
        "{:<18} {:>10} {:>12} {:>12} {:>9} {:>10}",
        "scheduler", "best acc", "budget(s)", "vs fifo", "stopped", "results"
    );
    println!("{}", "-".repeat(78));

    let mut fifo_budget = 0.0;
    let mut curves: Vec<(&'static str, Vec<(f64, f64)>)> = Vec::new();
    for (name, kind) in kinds(&space) {
        let mut best_acc = 0.0;
        let mut budget = 0.0;
        let mut stopped = 0u64;
        let mut results = 0u64;
        let mut curve: Vec<(f64, f64)> = Vec::new();
        for seed in SEEDS {
            let mut spec = ExperimentSpec::named(&format!("shootout-{name}-{seed}"));
            spec.metric = "accuracy".into();
            spec.mode = Mode::Max;
            spec.num_samples = SAMPLES;
            spec.max_iterations_per_trial = MAX_T;
            spec.seed = seed;
            let res = run_experiments(
                spec,
                space.clone(),
                kind.clone(),
                SearchKind::Random,
                factory(|c, s| Box::new(CurveTrainable::new(c, s))),
                RunOptions {
                    cluster: Cluster::uniform(4, Resources::cpu(8.0)),
                    ..Default::default()
                },
            );
            best_acc += res.best_metric().unwrap_or(0.0);
            budget += res.budget_used_s;
            stopped += res.stats.stopped_early;
            results += res.stats.results;
            if seed == SEEDS[0] {
                curve = res.best_curve.clone();
            }
        }
        let n = SEEDS.len() as f64;
        best_acc /= n;
        budget /= n;
        if name == "fifo" {
            fifo_budget = budget;
        }
        println!(
            "{:<18} {:>10.4} {:>12.0} {:>11.1}x {:>9} {:>10}",
            name,
            best_acc,
            budget,
            fifo_budget / budget,
            stopped / SEEDS.len() as u64,
            results / SEEDS.len() as u64
        );
        curves.push((name, curve));
    }

    // Best-found-vs-time curves (the "figure"): sampled at fixed times.
    println!("\nbest accuracy vs experiment time (seed {}):", SEEDS[0]);
    print!("{:>8}", "t(s)");
    for (name, _) in &curves {
        print!(" {name:>16}");
    }
    println!();
    for t in [10.0, 25.0, 50.0, 100.0, 200.0, 400.0] {
        print!("{t:>8.0}");
        for (_, curve) in &curves {
            let v = curve
                .iter()
                .take_while(|(ct, _)| *ct <= t)
                .last()
                .map(|(_, v)| *v)
                .unwrap_or(0.0);
            print!(" {v:>16.4}");
        }
        println!();
    }
    println!("\n(expected shape: asha/hyperband reach the fifo asymptote with 3-20x less budget)");
}
