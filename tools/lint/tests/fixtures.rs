//! Fixture-based self-tests: run the real binary against every
//! clean/violating fixture pair and assert on exit codes and the rule
//! names in the report.

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn fixtures_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures")
}

fn run_on(file: &str) -> Output {
    let dir = fixtures_dir();
    Command::new(env!("CARGO_BIN_EXE_tune-lint"))
        .arg("--config")
        .arg(dir.join("lint.toml"))
        .arg(dir.join(file))
        .output()
        .expect("spawn tune-lint")
}

fn assert_clean(file: &str) {
    let out = run_on(file);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "{file} should be clean but reported:\n{stdout}"
    );
    assert!(stdout.trim().is_empty(), "{file}: unexpected output:\n{stdout}");
}

fn assert_violates(file: &str, rule: &str) {
    let out = run_on(file);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(
        out.status.code(),
        Some(1),
        "{file} should exit 1, got {:?}:\n{stdout}",
        out.status.code()
    );
    assert!(
        stdout.lines().any(|l| l.contains(&format!(" {rule} — "))),
        "{file}: expected a `{rule}` violation, got:\n{stdout}"
    );
}

#[test]
fn clean_fixtures_pass() {
    for f in [
        "clean/nan.rs",
        "clean/order_home.rs",
        "clean/durability.rs",
        "clean/persist_home.rs",
        "clean/hash.rs",
        "clean/clock_allowed.rs",
        "clean/panics.rs",
        "clean/tests_tracking.rs",
    ] {
        assert_clean(f);
    }
}

#[test]
fn violating_fixtures_fail_with_their_rule() {
    for (f, rule) in [
        ("violating/nan.rs", "nan"),
        ("violating/durability.rs", "durability"),
        ("violating/hash_container.rs", "hash_container"),
        ("violating/hash_iteration.rs", "hash_iteration"),
        ("violating/clock.rs", "clock"),
        ("violating/panics.rs", "panic_budget"),
        ("violating/allow.rs", "allow_discipline"),
    ] {
        assert_violates(f, rule);
    }
}

#[test]
fn tree_mode_over_fixtures_reports_all_violating_files() {
    let dir = fixtures_dir();
    let out = Command::new(env!("CARGO_BIN_EXE_tune-lint"))
        .arg("--config")
        .arg(dir.join("lint.toml"))
        .output()
        .expect("spawn tune-lint");
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for rule in
        ["nan", "durability", "hash_container", "hash_iteration", "clock", "panic_budget"]
    {
        assert!(
            stdout.lines().any(|l| l.contains(&format!(" {rule} — "))),
            "tree mode missing `{rule}`:\n{stdout}"
        );
    }
    // Violations come out sorted by (file, line) for stable CI diffs.
    let files: Vec<&str> =
        stdout.lines().filter_map(|l| l.split(':').next()).collect();
    let mut sorted = files.clone();
    sorted.sort();
    assert_eq!(files, sorted, "report not sorted:\n{stdout}");
}

#[test]
fn config_allow_without_in_source_comment_is_a_violation() {
    use tune_lint::{lint_paths, Config, FileAllow};
    let dir = fixtures_dir();
    let mut cfg = Config::empty(dir.clone());
    cfg.clock_home = vec![];
    cfg.allows.push(FileAllow {
        rule: "clock".into(),
        file: "violating/clock.rs".into(),
        why: "pretend this is a wall-clock file".into(),
    });
    let report = lint_paths(&cfg, &[dir.join("violating/clock.rs")]).expect("lint");
    // The clock violations are suppressed by the file-level allow...
    assert!(report.violations.iter().all(|v| v.rule != "clock"), "{:?}", report.violations);
    // ...but the missing in-source justification comment is itself flagged.
    assert!(
        report
            .violations
            .iter()
            .any(|v| v.rule == "allow_discipline" && v.msg.contains("justification comment")),
        "{:?}",
        report.violations
    );
}

#[test]
fn unknown_flag_and_missing_config_are_usage_errors() {
    let out = Command::new(env!("CARGO_BIN_EXE_tune-lint"))
        .arg("--bogus")
        .output()
        .expect("spawn tune-lint");
    assert_eq!(out.status.code(), Some(2));
}
