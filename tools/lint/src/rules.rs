//! The rule engine: five repo invariants plus the allow-discipline
//! meta-rule, evaluated over the lexed token stream of each file.
//!
//! Every rule reports `file:line: rule — message`. Suppression happens
//! at two levels:
//!
//! * **site** — a `// lint:allow(rule): reason` comment suppresses
//!   same-rule violations on its own line and the line below it;
//! * **file** — a `[[allow]]` entry in `lint.toml` suppresses the rule
//!   for the whole file, but only if the file also carries at least
//!   one in-source `lint:allow(rule)` justification comment.
//!
//! Directives themselves are checked: an unknown rule name, an empty
//! reason, or a site directive that suppresses nothing is a violation
//! (`allow_discipline`), so the allowlist can only shrink honestly.

use std::collections::BTreeSet;
use std::path::Path;

use crate::config::Config;
use crate::lexer::{lex, Tok, TokKind};

/// Rule names a directive may reference.
pub const KNOWN_RULES: [&str; 6] =
    ["nan", "durability", "hash_container", "hash_iteration", "clock", "panic_budget"];

/// Hash-container methods whose call observes iteration order.
const ITER_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

/// One reported violation.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Violation {
    /// Root-relative file (or `lint.toml` for config-side problems).
    pub file: String,
    /// 1-based line number.
    pub line: u32,
    /// Rule identifier (`nan`, `durability`, …, `allow_discipline`).
    pub rule: &'static str,
    /// Human-readable detail.
    pub msg: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {} — {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Violations plus advisory notes (budget slack) from one run.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Hard failures; nonzero exit when non-empty.
    pub violations: Vec<Violation>,
    /// Advisory stderr notes that do not affect the exit code.
    pub notes: Vec<String>,
}

/// Is `rel` covered by `scopes`? Entries ending in `/` are directory
/// prefixes; everything else must match the whole path.
fn in_scope(rel: &str, scopes: &[String]) -> bool {
    scopes.iter().any(|s| if s.ends_with('/') { rel.starts_with(s.as_str()) } else { rel == s })
}

/// Do the tokens starting at `i` spell `pat` exactly?
fn seq_at(toks: &[Tok], i: usize, pat: &[&str]) -> bool {
    i + pat.len() <= toks.len() && pat.iter().enumerate().all(|(k, p)| toks[i + k].text == *p)
}

/// Lint a single file's source. Returns the final violations, notes,
/// and the set of rules the file carries justified directives for
/// (used by the tree-level allowlist cross-check).
pub fn lint_source(cfg: &Config, rel: &str, src: &str) -> (Report, BTreeSet<String>) {
    let lexed = lex(src);
    let (toks, directives) = (lexed.toks, lexed.directives);
    let mut raw: Vec<(u32, &'static str, String)> = Vec::new();
    let mut report = Report::default();

    let home = |scopes: &[String]| in_scope(rel, scopes);

    // nan: float comparisons must route through util::order.
    if !home(&cfg.nan_home) {
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokKind::Ident {
                continue;
            }
            if t.text == "partial_cmp" {
                raw.push((t.line, "nan", "raw partial_cmp (use util::order)".into()));
            }
            if t.text == "total_cmp" {
                raw.push((t.line, "nan", "raw total_cmp (use util::order)".into()));
            }
            if t.text == "impl" {
                let mut saw_ord = false;
                for tk in toks.iter().skip(i + 1).take(59) {
                    if tk.is_punct("{") || tk.is_punct(";") {
                        break;
                    }
                    if tk.kind == TokKind::Ident && (tk.text == "Ord" || tk.text == "PartialOrd") {
                        saw_ord = true;
                    }
                    if tk.is_ident("for") && saw_ord {
                        raw.push((
                            t.line,
                            "nan",
                            "hand-rolled Ord/PartialOrd impl (use util::order::OrdF64)".into(),
                        ));
                        break;
                    }
                }
            }
        }
    }

    // durability: file creation goes through persist::write_atomic*.
    if !home(&cfg.durability_home) {
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokKind::Ident {
                continue;
            }
            if seq_at(&toks, i, &["fs", ":", ":", "write"]) {
                raw.push((t.line, "durability", "fs::write (use persist::write_atomic)".into()));
            }
            if seq_at(&toks, i, &["File", ":", ":", "create"]) {
                raw.push((t.line, "durability", "File::create (use persist)".into()));
            }
            if t.text == "OpenOptions" {
                raw.push((t.line, "durability", "OpenOptions (use persist)".into()));
            }
        }
    }

    // hash_container: fingerprint-sensitive modules must not name
    // HashMap/HashSet at all.
    if in_scope(rel, &cfg.container_scopes) {
        for t in &toks {
            if !t.in_test
                && t.kind == TokKind::Ident
                && (t.text == "HashMap" || t.text == "HashSet")
            {
                raw.push((
                    t.line,
                    "hash_container",
                    format!("{} in a fingerprint-sensitive module (use BTreeMap/BTreeSet)", t.text),
                ));
            }
        }
    }

    // hash_iteration: taint names declared as hash containers, then
    // flag order-observing method calls and for-in loops on them.
    if in_scope(rel, &cfg.iteration_scopes) {
        let mut taint: BTreeSet<String> = BTreeSet::new();
        for (i, t) in toks.iter().enumerate() {
            if t.in_test
                || t.kind != TokKind::Ident
                || (t.text != "HashMap" && t.text != "HashSet")
            {
                continue;
            }
            if i >= 2 && toks[i - 1].is_punct(":") && toks[i - 2].kind == TokKind::Ident {
                taint.insert(toks[i - 2].text.clone());
            }
            if i >= 3
                && toks[i - 1].is_punct("=")
                && toks[i - 2].kind == TokKind::Ident
                && (toks[i - 3].is_ident("let") || toks[i - 3].is_ident("mut"))
            {
                taint.insert(toks[i - 2].text.clone());
            }
        }
        for (i, t) in toks.iter().enumerate() {
            if t.in_test {
                continue;
            }
            if t.kind == TokKind::Ident
                && ITER_METHODS.contains(&t.text.as_str())
                && i >= 2
                && toks[i - 1].is_punct(".")
                && toks[i - 2].kind == TokKind::Ident
                && taint.contains(&toks[i - 2].text)
                && i + 1 < toks.len()
                && toks[i + 1].is_punct("(")
            {
                raw.push((
                    t.line,
                    "hash_iteration",
                    format!(".{}() on hash container `{}`", t.text, toks[i - 2].text),
                ));
            }
            if t.is_ident("in") {
                let mut j = i + 1;
                while j < toks.len() && (toks[j].is_punct("&") || toks[j].is_ident("mut")) {
                    j += 1;
                }
                if j + 1 < toks.len() && toks[j].is_ident("self") && toks[j + 1].is_punct(".") {
                    j += 2;
                }
                if j + 1 < toks.len()
                    && toks[j].kind == TokKind::Ident
                    && taint.contains(&toks[j].text)
                    && toks[j + 1].is_punct("{")
                {
                    raw.push((
                        t.line,
                        "hash_iteration",
                        format!("for-in over hash container `{}`", toks[j].text),
                    ));
                }
            }
        }
    }

    // clock: Instant/SystemTime::now only in declared wall-clock code.
    if !home(&cfg.clock_home) {
        for (i, t) in toks.iter().enumerate() {
            if t.in_test {
                continue;
            }
            if seq_at(&toks, i, &["Instant", ":", ":", "now"])
                || seq_at(&toks, i, &["SystemTime", ":", ":", "now"])
            {
                raw.push((t.line, "clock", format!("{}::now in simulated-time code", t.text)));
            }
        }
    }

    // panic_budget: frozen unwrap/expect counts for hot-path files.
    if let Some(&(_, budget)) = cfg.budgets.iter().find(|(f, _)| f == rel) {
        let mut count: usize = 0;
        let mut over_line: u32 = 0;
        for (i, t) in toks.iter().enumerate() {
            if t.in_test || t.kind != TokKind::Ident {
                continue;
            }
            if (t.text == "unwrap" || t.text == "expect")
                && i >= 1
                && toks[i - 1].is_punct(".")
                && i + 1 < toks.len()
                && toks[i + 1].is_punct("(")
            {
                count += 1;
                if count == budget + 1 {
                    over_line = t.line;
                }
            }
        }
        if count > budget {
            raw.push((
                over_line,
                "panic_budget",
                format!("{count} non-test unwrap/expect calls exceed the frozen budget {budget}"),
            ));
        } else if count < budget {
            report.notes.push(format!(
                "{rel}: panic budget slack ({count} < {budget}) — tighten lint.toml"
            ));
        }
    }

    // Directive discipline: malformed directives are violations in
    // their own right, before any suppression happens.
    let mut used = vec![false; directives.len()];
    for d in &directives {
        if !KNOWN_RULES.contains(&d.rule.as_str()) {
            report.violations.push(Violation {
                file: rel.to_string(),
                line: d.line,
                rule: "allow_discipline",
                msg: format!("lint:allow names unknown rule `{}`", d.rule),
            });
        } else if d.reason.is_empty() {
            report.violations.push(Violation {
                file: rel.to_string(),
                line: d.line,
                rule: "allow_discipline",
                msg: format!("lint:allow({}) has no justification after the colon", d.rule),
            });
        }
    }

    // File-level allows from lint.toml mark same-rule directives used
    // (the in-source comment is their justification site).
    let file_allows: BTreeSet<&str> = cfg
        .allows
        .iter()
        .filter(|a| a.file == rel)
        .map(|a| a.rule.as_str())
        .collect();
    for (di, d) in directives.iter().enumerate() {
        if file_allows.contains(d.rule.as_str()) {
            used[di] = true;
        }
    }

    // Apply suppression: file-level first, then site directives that
    // sit on the violation line or the line above it.
    for (vline, vrule, vmsg) in raw {
        if file_allows.contains(vrule) {
            continue;
        }
        let mut suppressed = false;
        for (di, d) in directives.iter().enumerate() {
            if d.rule == vrule
                && !d.reason.is_empty()
                && (d.line == vline || d.line + 1 == vline)
            {
                used[di] = true;
                suppressed = true;
            }
        }
        if !suppressed {
            report.violations.push(Violation {
                file: rel.to_string(),
                line: vline,
                rule: vrule,
                msg: vmsg,
            });
        }
    }

    // A well-formed directive that suppresses nothing is stale.
    for (di, d) in directives.iter().enumerate() {
        if KNOWN_RULES.contains(&d.rule.as_str()) && !d.reason.is_empty() && !used[di] {
            report.violations.push(Violation {
                file: rel.to_string(),
                line: d.line,
                rule: "allow_discipline",
                msg: format!("lint:allow({}) suppresses nothing — remove it", d.rule),
            });
        }
    }

    let justified: BTreeSet<String> = directives
        .iter()
        .filter(|d| !d.reason.is_empty())
        .map(|d| d.rule.clone())
        .collect();
    (report, justified)
}

/// Collect `.rs` files under `root` in sorted (deterministic) order.
fn collect_rs_files(root: &Path) -> Result<Vec<std::path::PathBuf>, String> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        let rd = std::fs::read_dir(&dir).map_err(|e| format!("{}: {e}", dir.display()))?;
        let mut entries: Vec<_> = rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                stack.push(p);
            } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
                out.push(p);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint the whole tree under `cfg.root`, then cross-check the
/// config-side allowlist: every `[[allow]]` must have a `why`, point
/// at a file that exists, and be justified by an in-source directive.
pub fn lint_tree(cfg: &Config) -> Result<Report, String> {
    let files = collect_rs_files(&cfg.root)?;
    let mut report = Report::default();
    let mut justified: Vec<(String, BTreeSet<String>)> = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&cfg.root)
            .map_err(|_| format!("{}: outside root", path.display()))?
            .to_string_lossy()
            .replace('\\', "/");
        let src =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let (r, j) = lint_source(cfg, &rel, &src);
        report.violations.extend(r.violations);
        report.notes.extend(r.notes);
        justified.push((rel, j));
    }
    check_allowlist(cfg, &justified, &mut report);
    report.violations.sort();
    Ok(report)
}

/// Lint explicit file paths (fixture mode); paths are used verbatim as
/// the display name and scoped against `cfg.root`-relative rules via
/// their file name alone, so `cfg` should be built for the fixtures.
pub fn lint_paths(cfg: &Config, paths: &[std::path::PathBuf]) -> Result<Report, String> {
    let mut report = Report::default();
    let mut justified: Vec<(String, BTreeSet<String>)> = Vec::new();
    for path in paths {
        let rel = match path.strip_prefix(&cfg.root) {
            Ok(p) => p.to_string_lossy().replace('\\', "/"),
            Err(_) => path.to_string_lossy().replace('\\', "/"),
        };
        let src =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let (r, j) = lint_source(cfg, &rel, &src);
        report.violations.extend(r.violations);
        report.notes.extend(r.notes);
        justified.push((rel, j));
    }
    check_allowlist(cfg, &justified, &mut report);
    report.violations.sort();
    Ok(report)
}

fn check_allowlist(cfg: &Config, justified: &[(String, BTreeSet<String>)], report: &mut Report) {
    for a in &cfg.allows {
        if a.why.is_empty() {
            report.violations.push(Violation {
                file: "lint.toml".into(),
                line: 1,
                rule: "allow_discipline",
                msg: format!("allow({}) for {} has no `why`", a.rule, a.file),
            });
        }
        match justified.iter().find(|(rel, _)| *rel == a.file) {
            None => report.violations.push(Violation {
                file: "lint.toml".into(),
                line: 1,
                rule: "allow_discipline",
                msg: format!("stale allow entry: {} not found under root", a.file),
            }),
            Some((_, rules)) if !rules.contains(&a.rule) => {
                report.violations.push(Violation {
                    file: a.file.clone(),
                    line: 1,
                    rule: "allow_discipline",
                    msg: format!(
                        "lint.toml allows {} here but the file carries no \
                         lint:allow({}) justification comment",
                        a.rule, a.rule
                    ),
                })
            }
            Some(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FileAllow;
    use std::path::PathBuf;

    fn cfg_for(rel_scopes: impl FnOnce(&mut Config)) -> Config {
        let mut c = Config::empty(PathBuf::from("."));
        rel_scopes(&mut c);
        c
    }

    fn rules_of(r: &Report) -> Vec<&'static str> {
        r.violations.iter().map(|v| v.rule).collect()
    }

    #[test]
    fn nan_flags_partial_cmp_outside_home() {
        let cfg = cfg_for(|c| c.nan_home = vec!["util/order.rs".into()]);
        let src = "fn f(a: f64, b: f64) { a.partial_cmp(&b); }";
        let (r, _) = lint_source(&cfg, "coordinator/x.rs", src);
        assert_eq!(rules_of(&r), vec!["nan"]);
        let (r, _) = lint_source(&cfg, "util/order.rs", src);
        assert!(r.violations.is_empty());
    }

    #[test]
    fn nan_flags_hand_rolled_ord_impl() {
        let cfg = cfg_for(|_| {});
        let src = "struct W(f64);\nimpl Ord for W { fn cmp(&self, o: &W) -> O { todo() } }";
        let (r, _) = lint_source(&cfg, "a.rs", src);
        assert!(rules_of(&r).contains(&"nan"));
        // `impl Trait for T` without Ord/PartialOrd is fine.
        let (r, _) = lint_source(&cfg, "a.rs", "impl Display for W { }");
        assert!(r.violations.is_empty());
    }

    #[test]
    fn durability_flags_fs_write_but_not_in_tests() {
        let cfg = cfg_for(|c| c.durability_home = vec!["coordinator/persist.rs".into()]);
        let src = "fn f() { std::fs::write(p, b); }";
        let (r, _) = lint_source(&cfg, "a.rs", src);
        assert_eq!(rules_of(&r), vec!["durability"]);
        let test_src = "#[cfg(test)]\nmod tests { fn f() { std::fs::write(p, b); } }";
        let (r, _) = lint_source(&cfg, "a.rs", test_src);
        assert!(r.violations.is_empty());
    }

    #[test]
    fn hash_container_scoped_to_sensitive_modules() {
        let cfg = cfg_for(|c| c.container_scopes = vec!["coordinator/runner.rs".into()]);
        let src = "use std::collections::HashMap;";
        let (r, _) = lint_source(&cfg, "coordinator/runner.rs", src);
        assert_eq!(rules_of(&r), vec!["hash_container"]);
        let (r, _) = lint_source(&cfg, "logger/jsonl.rs", src);
        assert!(r.violations.is_empty());
    }

    #[test]
    fn hash_iteration_taints_declared_names() {
        let cfg = cfg_for(|c| c.iteration_scopes = vec!["coordinator/".into()]);
        let src = "struct S { live: HashMap<u64, T> }\n\
                   impl S { fn f(&self) { for (k, v) in &self.live { } } }";
        let (r, _) = lint_source(&cfg, "coordinator/x.rs", src);
        assert_eq!(rules_of(&r), vec!["hash_iteration"]);
        // Keyed access only: no violation.
        let keyed = "struct S { live: HashMap<u64, T> }\n\
                     impl S { fn f(&self) { self.live.get(&1); } }";
        let (r, _) = lint_source(&cfg, "coordinator/x.rs", keyed);
        assert!(r.violations.is_empty());
        // Method-call form.
        let m = "fn f(live: HashMap<u64, T>) { let _ = live.keys(); }";
        let (r, _) = lint_source(&cfg, "coordinator/x.rs", m);
        assert_eq!(rules_of(&r), vec!["hash_iteration"]);
    }

    #[test]
    fn clock_flags_instant_now() {
        let cfg = cfg_for(|c| c.clock_home = vec!["util/bench.rs".into()]);
        let src = "fn f() { let t = Instant::now(); }";
        let (r, _) = lint_source(&cfg, "coordinator/x.rs", src);
        assert_eq!(rules_of(&r), vec!["clock"]);
        let (r, _) = lint_source(&cfg, "util/bench.rs", src);
        assert!(r.violations.is_empty());
    }

    #[test]
    fn panic_budget_over_and_slack() {
        let cfg = cfg_for(|c| c.budgets = vec![("a.rs".into(), 1)]);
        let over = "fn f() { x.unwrap(); y.expect(\"m\"); }";
        let (r, _) = lint_source(&cfg, "a.rs", over);
        assert_eq!(rules_of(&r), vec!["panic_budget"]);
        let slack = "fn f() { }";
        let (r, _) = lint_source(&cfg, "a.rs", slack);
        assert!(r.violations.is_empty());
        assert_eq!(r.notes.len(), 1);
    }

    #[test]
    fn site_directive_suppresses_same_and_next_line_only() {
        let cfg = cfg_for(|_| {});
        let ok = "// lint:allow(clock): wall-clock probe for the worker heartbeat\n\
                  fn f() { let t = Instant::now(); }";
        let (r, _) = lint_source(&cfg, "a.rs", ok);
        assert!(r.violations.is_empty(), "{:?}", r.violations);
        let far = "// lint:allow(clock): too far away\n\nfn f() { let t = Instant::now(); }";
        let (r, _) = lint_source(&cfg, "a.rs", far);
        // The clock violation survives AND the directive reads stale.
        let rs = rules_of(&r);
        assert!(rs.contains(&"clock") && rs.contains(&"allow_discipline"));
    }

    #[test]
    fn directive_without_reason_or_with_unknown_rule_is_violation() {
        let cfg = cfg_for(|_| {});
        let (r, _) = lint_source(&cfg, "a.rs", "// lint:allow(clock)\nfn f() {}");
        assert_eq!(rules_of(&r), vec!["allow_discipline"]);
        let (r, _) = lint_source(&cfg, "a.rs", "// lint:allow(made_up): because\nfn f() {}");
        assert_eq!(rules_of(&r), vec!["allow_discipline"]);
    }

    #[test]
    fn file_allow_needs_in_source_justification() {
        let mut cfg = cfg_for(|c| c.clock_home = vec!["util/bench.rs".into()]);
        cfg.allows.push(FileAllow {
            rule: "clock".into(),
            file: "a.rs".into(),
            why: "wall-clock file".into(),
        });
        // Without the in-source comment, the cross-check fires.
        let (r, j) = lint_source(&cfg, "a.rs", "fn f() { Instant::now(); }");
        assert!(r.violations.is_empty(), "file allow should suppress: {:?}", r.violations);
        let mut report = Report::default();
        check_allowlist(&cfg, &[("a.rs".into(), j)], &mut report);
        assert_eq!(rules_of(&report), vec!["allow_discipline"]);
        // With it, everything is quiet.
        let src = "// lint:allow(clock): this whole file is the wall-clock substrate\n\
                   fn f() { Instant::now(); }";
        let (r, j) = lint_source(&cfg, "a.rs", src);
        assert!(r.violations.is_empty());
        let mut report = Report::default();
        check_allowlist(&cfg, &[("a.rs".into(), j)], &mut report);
        assert!(report.violations.is_empty(), "{:?}", report.violations);
    }

    #[test]
    fn allow_entry_missing_why_is_violation() {
        let mut cfg = cfg_for(|_| {});
        cfg.allows.push(FileAllow { rule: "clock".into(), file: "a.rs".into(), why: "".into() });
        let mut report = Report::default();
        let mut j = BTreeSet::new();
        j.insert("clock".to_string());
        check_allowlist(&cfg, &[("a.rs".into(), j)], &mut report);
        assert_eq!(rules_of(&report), vec!["allow_discipline"]);
    }

    #[test]
    fn stale_allow_entry_is_violation() {
        let mut cfg = cfg_for(|_| {});
        cfg.allows.push(FileAllow {
            rule: "clock".into(),
            file: "gone.rs".into(),
            why: "was removed".into(),
        });
        let mut report = Report::default();
        check_allowlist(&cfg, &[], &mut report);
        assert_eq!(rules_of(&report), vec!["allow_discipline"]);
    }
}
