//! A lightweight Rust lexer: just enough structure for invariant rules.
//!
//! This is not a parser. It produces a flat token stream with three
//! properties the rules need and plain `grep` cannot deliver:
//!
//! * **Literals and comments are opaque** — `"Instant::now"` inside a
//!   string, a doc example, or a nested block comment never matches a
//!   rule. Normal, byte, C and raw strings (`r#"…"#` with any hash
//!   count) are handled, and `'a'` char literals are distinguished from
//!   `'a` lifetimes.
//! * **Test code is marked** — tokens inside a `#[cfg(test)]` item (of
//!   any shape: module, function, `use`) or an unattributed inline
//!   `mod tests { … }` carry `in_test = true`, so every rule can exempt
//!   test code without a parallel source layout.
//! * **`lint:allow` directives survive** — comments are stripped from
//!   the token stream, but `// lint:allow(rule): justification`
//!   directives found inside them are collected with their line, rule
//!   name and justification text for the suppression machinery.

/// Kind of one lexed token.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fs`, `impl`, `for`, …).
    Ident,
    /// A single punctuation byte (`::` is two `:` tokens).
    Punct,
    /// Any literal — string/char/number — with its text blanked.
    Literal,
    /// A lifetime (`'a`); kept distinct so it never reads as a char.
    Lifetime,
}

/// One token with its 1-based source line and test-code marker.
#[derive(Clone, Debug)]
pub struct Tok {
    /// 1-based line the token starts on.
    pub line: u32,
    /// Token class.
    pub kind: TokKind,
    /// Identifier/punct text; empty for literals.
    pub text: String,
    /// True when the token sits inside `#[cfg(test)]` or `mod tests`.
    pub in_test: bool,
}

impl Tok {
    /// True when this token is the identifier `s`.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    /// True when this token is the punctuation `s`.
    pub fn is_punct(&self, s: &str) -> bool {
        self.kind == TokKind::Punct && self.text == s
    }
}

/// An in-source `lint:allow(rule): justification` directive.
#[derive(Clone, Debug)]
pub struct Directive {
    /// Line the directive text appears on (not the comment start).
    pub line: u32,
    /// Rule name between the parentheses (may be empty if malformed).
    pub rule: String,
    /// Justification after the trailing colon; empty when missing.
    pub reason: String,
}

/// Lexed view of one source file.
#[derive(Debug, Default)]
pub struct LexFile {
    /// The comment- and literal-stripped token stream.
    pub toks: Vec<Tok>,
    /// Every `lint:allow` directive found in comments.
    pub directives: Vec<Directive>,
}

fn is_ident_start(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphabetic() || c >= 0x80
}

fn is_ident_cont(c: u8) -> bool {
    is_ident_start(c) || c.is_ascii_digit()
}

/// Collect `lint:allow(rule): reason` directives out of comment text.
/// `start_line` is the line the comment text begins on; embedded
/// newlines (block comments) offset the recorded directive line.
fn scan_directives(text: &str, start_line: u32, out: &mut Vec<Directive>) {
    const NEEDLE: &str = "lint:allow(";
    let mut pos = 0;
    while let Some(off) = text[pos..].find(NEEDLE) {
        let idx = pos + off;
        let line = start_line + text[..idx].bytes().filter(|&b| b == b'\n').count() as u32;
        let after = &text[idx + NEEDLE.len()..];
        match after.find(')') {
            None => out.push(Directive { line, rule: String::new(), reason: String::new() }),
            Some(close) => {
                let rule = after[..close].trim().to_string();
                let rest = &after[close + 1..];
                let reason = match rest.strip_prefix(':') {
                    None => String::new(),
                    Some(tail) => {
                        let seg = tail.split('\n').next().unwrap_or("");
                        // A block-comment terminator on the same line is
                        // not part of the justification.
                        seg.replace("*/", " ").trim().to_string()
                    }
                };
                out.push(Directive { line, rule, reason });
            }
        }
        pos = idx + 1;
    }
}

/// Consume a `"…"` string body starting at the opening quote; returns
/// the index past the closing quote, updating the line counter.
fn skip_string(b: &[u8], mut i: usize, line: &mut u32) -> usize {
    i += 1;
    while i < b.len() {
        match b[i] {
            b'\\' => {
                if i + 1 < b.len() && b[i + 1] == b'\n' {
                    *line += 1;
                }
                i += 2;
            }
            b'\n' => {
                *line += 1;
                i += 1;
            }
            b'"' => return i + 1,
            _ => i += 1,
        }
    }
    i
}

/// Lex one source file. Never fails: unterminated constructs are
/// consumed to end-of-file (a linter must not panic on weird input).
pub fn lex(src: &str) -> LexFile {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut directives: Vec<Directive> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    let lit = |line: u32| Tok { line, kind: TokKind::Literal, text: String::new(), in_test: false };
    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            scan_directives(&src[start..i], line, &mut directives);
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let (start, start_line) = (i, line);
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            scan_directives(&src[start..i], start_line, &mut directives);
            continue;
        }
        if c == b'"' {
            i = skip_string(b, i, &mut line);
            toks.push(lit(line));
            continue;
        }
        if c == b'\'' {
            if i + 1 < n && b[i + 1] == b'\\' {
                // Escaped char literal: scan to the closing quote.
                i += 2;
                while i < n && b[i] != b'\'' {
                    i += 1;
                }
                i += 1;
                toks.push(lit(line));
            } else if i + 2 < n && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
                i += 3;
                toks.push(lit(line));
            } else {
                // Lifetime: tick + identifier.
                i += 1;
                let s = i;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
                toks.push(Tok {
                    line,
                    kind: TokKind::Lifetime,
                    text: src[s..i].to_string(),
                    in_test: false,
                });
            }
            continue;
        }
        if is_ident_start(c) {
            let s = i;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            let word = &src[s..i];
            // Raw / byte / C string prefixes and raw identifiers.
            if matches!(word, "r" | "br" | "cr") && i < n && (b[i] == b'"' || b[i] == b'#') {
                let mut h = 0usize;
                let mut j = i;
                while j < n && b[j] == b'#' {
                    h += 1;
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    // Raw string with `h` hashes: find `"` + h hashes.
                    j += 1;
                    let closer: Vec<u8> =
                        std::iter::once(b'"').chain(std::iter::repeat(b'#').take(h)).collect();
                    let end = find_sub(&b[j..], &closer).map(|off| j + off).unwrap_or(n);
                    line += b[i..end.min(n)].iter().filter(|&&x| x == b'\n').count() as u32;
                    i = (end + closer.len()).min(n);
                    toks.push(lit(line));
                    continue;
                }
                if word == "r" && h >= 1 {
                    // Raw identifier r#foo: token is the bare name.
                    i += 1;
                    let s2 = i;
                    while i < n && is_ident_cont(b[i]) {
                        i += 1;
                    }
                    toks.push(Tok {
                        line,
                        kind: TokKind::Ident,
                        text: src[s2..i].to_string(),
                        in_test: false,
                    });
                    continue;
                }
            }
            if matches!(word, "b" | "c") && i < n && b[i] == b'"' {
                i = skip_string(b, i, &mut line);
                toks.push(lit(line));
                continue;
            }
            if word == "b" && i < n && b[i] == b'\'' {
                i += 1;
                if i < n && b[i] == b'\\' {
                    i += 1;
                    while i < n && b[i] != b'\'' {
                        i += 1;
                    }
                    i += 1;
                } else {
                    i = (i + 2).min(n);
                }
                toks.push(lit(line));
                continue;
            }
            toks.push(Tok { line, kind: TokKind::Ident, text: word.to_string(), in_test: false });
            continue;
        }
        if c.is_ascii_digit() {
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            // `1.5` continues the number; `0..8` does not.
            if i + 1 < n && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
            }
            toks.push(lit(line));
            continue;
        }
        toks.push(Tok {
            line,
            kind: TokKind::Punct,
            text: (c as char).to_string(),
            in_test: false,
        });
        i += 1;
    }
    mark_tests(&mut toks);
    LexFile { toks, directives }
}

fn find_sub(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || haystack.len() < needle.len() {
        return None;
    }
    (0..=haystack.len() - needle.len()).find(|&k| &haystack[k..k + needle.len()] == needle)
}

/// True when the attribute token slice contains the exact sequence
/// `cfg ( test )` (the canonical `#[cfg(test)]` form; `any`/`all`
/// compositions are deliberately not recognized — the repo does not use
/// them, and guessing wrong would silently exempt real code).
fn attr_is_cfg_test(attr: &[Tok]) -> bool {
    attr.windows(4).any(|w| {
        w[0].is_ident("cfg") && w[1].is_punct("(") && w[2].is_ident("test") && w[3].is_punct(")")
    })
}

/// Second pass: mark tokens inside test-only regions. Tracks brace
/// depth; a `#[cfg(test)]` outer attribute arms a pending marker that
/// claims the next `{ … }` block (or is discharged by a `;` for
/// body-less items), and an unattributed inline `mod tests {` block is
/// claimed the same way. `#![cfg(test)]` at file scope marks the whole
/// file.
fn mark_tests(toks: &mut [Tok]) {
    let n = toks.len();
    let mut depth: i64 = 0;
    let mut stack: Vec<i64> = Vec::new();
    let mut pending = false;
    let mut i = 0usize;
    while i < n {
        if toks[i].is_punct("#") && i + 1 < n {
            let j = if toks[i + 1].is_punct("!") { i + 2 } else { i + 1 };
            let inner = j == i + 2;
            if j < n && toks[j].is_punct("[") {
                let mut d = 0i64;
                let mut k = j;
                while k < n {
                    if toks[k].is_punct("[") {
                        d += 1;
                    } else if toks[k].is_punct("]") {
                        d -= 1;
                        if d == 0 {
                            break;
                        }
                    }
                    k += 1;
                }
                let k = k.min(n - 1);
                let is_test = attr_is_cfg_test(&toks[j..=k]);
                let in_t = !stack.is_empty();
                for t in &mut toks[i..=k] {
                    t.in_test = in_t;
                }
                if is_test {
                    if inner && depth == 0 {
                        // `#![cfg(test)]`: the entire file is test code.
                        stack.push(-1);
                    } else if !inner {
                        pending = true;
                    }
                }
                i = k + 1;
                continue;
            }
        }
        if toks[i].is_punct("{") {
            depth += 1;
            if pending {
                stack.push(depth - 1);
                pending = false;
            }
            toks[i].in_test = !stack.is_empty();
        } else if toks[i].is_punct("}") {
            depth -= 1;
            toks[i].in_test = !stack.is_empty();
            if stack.last() == Some(&depth) {
                stack.pop();
            }
        } else if toks[i].is_punct(";") && pending {
            // `#[cfg(test)] use …;` — no block to claim.
            pending = false;
            toks[i].in_test = !stack.is_empty();
        } else if toks[i].is_ident("mod")
            && i + 1 < n
            && toks[i + 1].is_ident("tests")
            && stack.is_empty()
        {
            pending = true;
            toks[i].in_test = false;
        } else {
            toks[i].in_test = !stack.is_empty();
        }
        i += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(lf: &LexFile) -> Vec<(&str, bool)> {
        lf.toks
            .iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| (t.text.as_str(), t.in_test))
            .collect()
    }

    #[test]
    fn strings_and_comments_are_opaque() {
        let lf = lex(concat!(
            "let s = \"Instant::now partial_cmp\";\n",
            "// fs::write in a line comment\n",
            "let c = 'x'; let esc = '\\n';\n",
            "let r = r#\"OpenOptions \"quoted\" inside\"#;\n",
            "let b = b\"File::create\";\n",
            "call(real_ident);\n",
        ));
        let names: Vec<&str> = idents(&lf).iter().map(|(t, _)| *t).collect();
        assert!(!names.contains(&"Instant"));
        assert!(!names.contains(&"fs"));
        assert!(!names.contains(&"OpenOptions"));
        assert!(!names.contains(&"File"));
        assert!(names.contains(&"real_ident"));
    }

    #[test]
    fn nested_block_comments_strip_fully() {
        let lf = lex("before /* outer /* inner Instant::now */ still comment */ after");
        let names: Vec<&str> = idents(&lf).iter().map(|(t, _)| *t).collect();
        assert_eq!(names, vec!["before", "after"]);
    }

    #[test]
    fn lifetimes_do_not_eat_code() {
        let lf = lex("fn f<'a>(x: &'a str) -> &'a str { x }");
        let names: Vec<&str> = idents(&lf).iter().map(|(t, _)| *t).collect();
        assert!(names.contains(&"str"));
        assert!(lf.toks.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "a"));
    }

    #[test]
    fn raw_string_with_hashes_spans_quotes() {
        let lf = lex("let s = r##\"one \"# two\"##; tail();");
        let names: Vec<&str> = idents(&lf).iter().map(|(t, _)| *t).collect();
        assert!(names.contains(&"tail"));
        assert!(!names.contains(&"one"));
    }

    #[test]
    fn cfg_test_inline_module_is_marked() {
        let lf = lex(concat!(
            "fn live() { touch(); }\n",
            "#[cfg(test)]\n",
            "mod checks {\n",
            "    fn helper() { test_only(); }\n",
            "}\n",
            "fn live2() { touch2(); }\n",
        ));
        let m: Vec<(&str, bool)> = idents(&lf);
        assert!(m.contains(&("touch", false)));
        assert!(m.contains(&("test_only", true)));
        assert!(m.contains(&("touch2", false)));
    }

    #[test]
    fn bare_mod_tests_is_marked() {
        let lf = lex("fn live() {}\nmod tests { fn t() { inside(); } }\nfn after() { out(); }");
        let m = idents(&lf);
        assert!(m.contains(&("inside", true)));
        assert!(m.contains(&("out", false)));
    }

    #[test]
    fn cfg_test_fn_and_attr_stacking() {
        let lf = lex(concat!(
            "#[cfg(test)]\n",
            "#[allow(dead_code)]\n",
            "fn probe() { test_only(); }\n",
            "fn live() { outside(); }\n",
            "#[cfg(test)]\n",
            "use std::vec::Vec;\n",
            "fn live2() { outside2(); }\n",
        ));
        let m = idents(&lf);
        assert!(m.contains(&("test_only", true)));
        assert!(m.contains(&("outside", false)));
        assert!(m.contains(&("outside2", false)));
    }

    #[test]
    fn cfg_not_test_is_not_test_code() {
        let lf = lex("#[cfg(not(test))]\nfn live() { touch(); }");
        assert!(idents(&lf).contains(&("touch", false)));
    }

    #[test]
    fn directives_parse_rule_and_reason() {
        let lf = lex(concat!(
            "let m = 1; // lint:allow(hash_container): keyed lookup only, never iterated\n",
            "// lint:allow(clock)\n",
            "/* lint:allow(durability): block form */\n",
        ));
        assert_eq!(lf.directives.len(), 3);
        assert_eq!(lf.directives[0].line, 1);
        assert_eq!(lf.directives[0].rule, "hash_container");
        assert_eq!(lf.directives[0].reason, "keyed lookup only, never iterated");
        assert_eq!(lf.directives[1].line, 2);
        assert_eq!(lf.directives[1].rule, "clock");
        assert_eq!(lf.directives[1].reason, "");
        assert_eq!(lf.directives[2].rule, "durability");
        assert_eq!(lf.directives[2].reason, "block form");
    }

    #[test]
    fn directive_line_inside_multiline_block_comment() {
        let lf = lex("/*\n  text\n  lint:allow(nan): deep in a block\n*/\n");
        assert_eq!(lf.directives.len(), 1);
        assert_eq!(lf.directives[0].line, 3);
    }

    #[test]
    fn unterminated_constructs_do_not_panic() {
        lex("let s = \"never closed");
        lex("/* never closed");
        lex("let r = r#\"never closed");
        lex("'");
    }
}
