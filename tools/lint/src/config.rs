//! `lint.toml` loading — a deliberately tiny TOML subset.
//!
//! The configuration needs tables, arrays-of-tables, strings, integers
//! and single-line string arrays; nothing else. The parser is strict:
//! an unknown table or key is a hard error, so a typo in `lint.toml`
//! fails the build instead of silently disabling a rule.

use std::path::{Path, PathBuf};

/// One file-level allowlist entry (`[[allow]]` in `lint.toml`).
///
/// A file-level entry suppresses every violation of `rule` in `file`,
/// but only counts as justified if the file itself carries at least one
/// `// lint:allow(rule): …` comment — the justification must live next
/// to the code it excuses, not only in the config.
#[derive(Clone, Debug)]
pub struct FileAllow {
    /// Rule being exempted (e.g. `hash_container`).
    pub rule: String,
    /// Root-relative file the exemption applies to.
    pub file: String,
    /// Why the exemption exists (config-side summary).
    pub why: String,
}

/// Parsed linter configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Absolute directory the rules walk (`rust/src` in this repo).
    pub root: PathBuf,
    /// Files where raw float ordering is the point (util/order.rs).
    pub nan_home: Vec<String>,
    /// Files allowed to create/write files directly (persist.rs).
    pub durability_home: Vec<String>,
    /// Fingerprint-sensitive scopes where `HashMap`/`HashSet` are
    /// banned outright. Entries ending in `/` are directory prefixes.
    pub container_scopes: Vec<String>,
    /// Scopes where *iterating* a hash container is banned.
    pub iteration_scopes: Vec<String>,
    /// Files whose business is the wall clock (util/bench.rs).
    pub clock_home: Vec<String>,
    /// Frozen per-file `unwrap()/expect()` budgets for hot-path files.
    pub budgets: Vec<(String, usize)>,
    /// File-level rule exemptions.
    pub allows: Vec<FileAllow>,
}

impl Config {
    /// An empty config rooted at `root` — the starting point tests use
    /// to build configurations programmatically.
    pub fn empty(root: PathBuf) -> Config {
        Config {
            root,
            nan_home: Vec::new(),
            durability_home: Vec::new(),
            container_scopes: Vec::new(),
            iteration_scopes: Vec::new(),
            clock_home: Vec::new(),
            budgets: Vec::new(),
            allows: Vec::new(),
        }
    }

    /// Load and parse `path`, resolving `root` relative to its parent
    /// directory.
    pub fn load(path: &Path) -> Result<Config, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))?;
        let dir = path.parent().unwrap_or(Path::new("."));
        Self::parse(&text, dir)
    }

    /// Parse config text; `config_dir` anchors the `root` key.
    pub fn parse(text: &str, config_dir: &Path) -> Result<Config, String> {
        let mut cfg = Config::empty(config_dir.to_path_buf());
        let mut root_rel = String::from("rust/src");
        let mut table = String::new();
        for (ln, line) in logical_lines(text) {
            if line.is_empty() {
                continue;
            }
            let line = line.as_str();
            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim();
                if name != "allow" {
                    return Err(format!("lint.toml:{ln}: unknown array table [[{name}]]"));
                }
                cfg.allows.push(FileAllow {
                    rule: String::new(),
                    file: String::new(),
                    why: String::new(),
                });
                table = "allow".into();
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                table = name.trim().to_string();
                match table.as_str() {
                    "nan" | "durability" | "determinism" | "clock" | "panic_budget"
                    | "panic_budget.budgets" => {}
                    other => return Err(format!("lint.toml:{ln}: unknown table [{other}]")),
                }
                continue;
            }
            let (key, val) = split_key_value(&line)
                .ok_or_else(|| format!("lint.toml:{ln}: expected `key = value`"))?;
            match (table.as_str(), key.as_str()) {
                ("", "root") => root_rel = val.as_str(ln)?,
                ("nan", "home") => cfg.nan_home = val.as_str_array(ln)?,
                ("durability", "home") => cfg.durability_home = val.as_str_array(ln)?,
                ("determinism", "container_scopes") => {
                    cfg.container_scopes = val.as_str_array(ln)?
                }
                ("determinism", "iteration_scopes") => {
                    cfg.iteration_scopes = val.as_str_array(ln)?
                }
                ("clock", "home") => cfg.clock_home = val.as_str_array(ln)?,
                ("panic_budget.budgets", file) => {
                    cfg.budgets.push((file.to_string(), val.as_int(ln)?))
                }
                ("allow", field) => {
                    let entry = cfg
                        .allows
                        .last_mut()
                        .ok_or_else(|| format!("lint.toml:{ln}: key outside [[allow]]"))?;
                    match field {
                        "rule" => entry.rule = val.as_str(ln)?,
                        "file" => entry.file = val.as_str(ln)?,
                        "why" => entry.why = val.as_str(ln)?,
                        other => {
                            return Err(format!("lint.toml:{ln}: unknown allow key `{other}`"))
                        }
                    }
                }
                (t, k) => return Err(format!("lint.toml:{ln}: unknown key `{k}` in [{t}]")),
            }
        }
        cfg.root = config_dir.join(root_rel);
        Ok(cfg)
    }
}

/// Raw right-hand-side value before typing.
struct Value(String);

impl Value {
    fn as_str(&self, ln: usize) -> Result<String, String> {
        unquote(self.0.trim())
            .ok_or_else(|| format!("lint.toml:{ln}: expected a quoted string, got `{}`", self.0))
    }

    fn as_int(&self, ln: usize) -> Result<usize, String> {
        self.0
            .trim()
            .parse::<usize>()
            .map_err(|_| format!("lint.toml:{ln}: expected an integer, got `{}`", self.0))
    }

    fn as_str_array(&self, ln: usize) -> Result<Vec<String>, String> {
        let t = self.0.trim();
        let inner = t
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
            .ok_or_else(|| format!("lint.toml:{ln}: expected a single-line string array"))?;
        let mut out = Vec::new();
        for part in split_top_level_commas(inner) {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            out.push(unquote(part).ok_or_else(|| {
                format!("lint.toml:{ln}: expected quoted strings in array, got `{part}`")
            })?);
        }
        Ok(out)
    }
}

/// Comment-strip and trim each physical line, joining continuation
/// lines of a multi-line `[...]` value (bracket depth counted outside
/// quotes) into one logical line tagged with its starting line number.
fn logical_lines(text: &str) -> Vec<(usize, String)> {
    let mut out: Vec<(usize, String)> = Vec::new();
    let mut depth: i32 = 0;
    for (ln0, raw) in text.lines().enumerate() {
        let piece = strip_comment(raw).trim().to_string();
        if depth > 0 {
            if let Some((_, cur)) = out.last_mut() {
                cur.push(' ');
                cur.push_str(&piece);
            }
        } else {
            out.push((ln0 + 1, piece));
        }
        let mut in_str = false;
        for c in strip_comment(raw).chars() {
            match c {
                '"' => in_str = !in_str,
                '[' if !in_str => depth += 1,
                ']' if !in_str => depth -= 1,
                _ => {}
            }
        }
        depth = depth.max(0);
    }
    out
}

/// Strip a `#` comment, honoring quotes.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Split `key = value` at the first `=` outside quotes; the key may be
/// bare or quoted (`"coordinator/runner.rs" = 12`).
fn split_key_value(line: &str) -> Option<(String, Value)> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => {
                let key_raw = line[..i].trim();
                let key = unquote(key_raw).unwrap_or_else(|| key_raw.to_string());
                return Some((key, Value(line[i + 1..].to_string())));
            }
            _ => {}
        }
    }
    None
}

fn unquote(s: &str) -> Option<String> {
    s.strip_prefix('"')?.strip_suffix('"').map(|inner| inner.to_string())
}

fn split_top_level_commas(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# comment
root = "rust/src"

[nan]
home = ["util/order.rs"]

[determinism]
container_scopes = ["coordinator/runner.rs", "ray/"]
iteration_scopes = ["coordinator/", "ray/"]

[panic_budget.budgets]
"coordinator/runner.rs" = 15

[[allow]]
rule = "clock"
file = "coordinator/executor.rs"
why = "wall-clock substrates"
"#;

    #[test]
    fn parses_the_full_shape() {
        let cfg = Config::parse(SAMPLE, Path::new("/repo")).expect("parse");
        assert_eq!(cfg.root, PathBuf::from("/repo/rust/src"));
        assert_eq!(cfg.nan_home, vec!["util/order.rs"]);
        assert_eq!(cfg.container_scopes, vec!["coordinator/runner.rs", "ray/"]);
        assert_eq!(cfg.budgets, vec![("coordinator/runner.rs".to_string(), 15)]);
        assert_eq!(cfg.allows.len(), 1);
        assert_eq!(cfg.allows[0].rule, "clock");
        assert_eq!(cfg.allows[0].file, "coordinator/executor.rs");
        assert_eq!(cfg.allows[0].why, "wall-clock substrates");
    }

    #[test]
    fn unknown_table_and_key_are_hard_errors() {
        assert!(Config::parse("[nope]\n", Path::new(".")).is_err());
        assert!(Config::parse("[nan]\nhom = [\"x\"]\n", Path::new(".")).is_err());
        assert!(Config::parse("[[allows]]\n", Path::new(".")).is_err());
    }

    #[test]
    fn multiline_arrays_join_into_one_logical_line() {
        let cfg = Config::parse(
            "[determinism]\ncontainer_scopes = [\n  \"a.rs\", # inline comment\n  \"b/\",\n]\n",
            Path::new("."),
        )
        .expect("parse");
        assert_eq!(cfg.container_scopes, vec!["a.rs", "b/"]);
    }

    #[test]
    fn quoted_keys_and_hash_in_strings() {
        let cfg = Config::parse(
            "[panic_budget.budgets]\n\"a/b.rs\" = 3 # trailing comment\n",
            Path::new("."),
        )
        .expect("parse");
        assert_eq!(cfg.budgets, vec![("a/b.rs".to_string(), 3)]);
    }
}
