//! `tune-lint` — the repo-specific invariant linter.
//!
//! Seven PRs of this Tune reproduction accumulated coding disciplines
//! that its headline guarantees rest on: NaN-total metric ordering
//! through `util::order`, atomic persistence through
//! `persist::write_atomic*`, deterministic (hash-free) iteration in
//! fingerprinted modules, no wall clocks in the simulated path, and a
//! frozen unwrap budget on hot-path files. This crate mechanizes those
//! disciplines as a zero-dependency lexical pass over `rust/src/**`,
//! configured by the checked-in `lint.toml`.
//!
//! Run it with `cargo run -p tune-lint` from the workspace root. It
//! prints `file:line: rule — message` per violation and exits nonzero
//! if any remain.

#![warn(missing_docs)]
#![forbid(unsafe_code)]

pub mod config;
pub mod lexer;
pub mod rules;

pub use config::{Config, FileAllow};
pub use lexer::{lex, Directive, LexFile, Tok, TokKind};
pub use rules::{lint_paths, lint_source, lint_tree, Report, Violation, KNOWN_RULES};
