//! CLI entry point for `tune-lint`.
//!
//! Usage:
//!
//! ```text
//! tune-lint [--config PATH] [FILE ...]
//! ```
//!
//! With no arguments, finds `lint.toml` by walking up from the current
//! directory and lints the whole configured tree. With explicit FILE
//! arguments, lints just those files under the same config (used by
//! the fixture suite). Exit codes: 0 clean, 1 violations, 2 usage or
//! I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use tune_lint::{lint_paths, lint_tree, Config};

fn find_config() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let candidate = dir.join("lint.toml");
        if candidate.is_file() {
            return Some(candidate);
        }
        if !dir.pop() {
            return None;
        }
    }
}

fn run() -> Result<bool, String> {
    let mut config_path: Option<PathBuf> = None;
    let mut files: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--config" => {
                let p = args.next().ok_or("--config needs a path")?;
                config_path = Some(PathBuf::from(p));
            }
            "--help" | "-h" => {
                println!("usage: tune-lint [--config PATH] [FILE ...]");
                return Ok(true);
            }
            other if other.starts_with('-') => {
                return Err(format!("unknown flag `{other}`"));
            }
            other => files.push(PathBuf::from(other)),
        }
    }
    let config_path = match config_path.or_else(find_config) {
        Some(p) => p,
        None => return Err("no lint.toml found here or in any parent directory".into()),
    };
    let cfg = Config::load(&config_path)?;
    let report =
        if files.is_empty() { lint_tree(&cfg)? } else { lint_paths(&cfg, &files)? };
    for v in &report.violations {
        println!("{v}");
    }
    for n in &report.notes {
        eprintln!("note: {n}");
    }
    if report.violations.is_empty() {
        eprintln!("tune-lint: clean ({})", config_path.display());
        Ok(true)
    } else {
        eprintln!("tune-lint: {} violation(s)", report.violations.len());
        Ok(false)
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::from(0),
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("tune-lint: error: {e}");
            ExitCode::from(2)
        }
    }
}
