//! Violating: every way a lint:allow directive can itself be wrong.

// lint:allow(clock)
pub fn missing_reason() {}

// lint:allow(made_up_rule): confidently excusing a rule that does not exist
pub fn unknown_rule() {}

// lint:allow(durability): justified, but there is nothing here to suppress
pub fn stale_directive() {}
