//! Violating: hash containers named inside a fingerprint-sensitive
//! module — iteration order would leak into fingerprints.

use std::collections::{HashMap, HashSet};

pub struct State {
    pub live: HashMap<u64, u64>,
    pub seen: HashSet<u64>,
}
