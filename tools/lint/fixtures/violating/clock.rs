//! Violating: wall-clock reads in simulated-time code, no directive.

use std::time::{Instant, SystemTime};

pub fn stamp() -> (Instant, SystemTime) {
    (Instant::now(), SystemTime::now())
}
