//! Violating: direct file creation in non-test code outside the
//! durability home — a torn write waiting for a power cut.

use std::fs::{File, OpenOptions};

pub fn save(path: &std::path::Path, text: &str) -> std::io::Result<()> {
    std::fs::write(path, text)
}

pub fn open_log(path: &std::path::Path) -> std::io::Result<File> {
    OpenOptions::new().create(true).append(true).open(path)
}

pub fn truncate(path: &std::path::Path) -> std::io::Result<File> {
    File::create(path)
}
