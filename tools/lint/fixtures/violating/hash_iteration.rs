//! Violating: iterating hash containers in an iteration-sensitive
//! scope, in both method-call and for-in form.

use std::collections::HashMap;

pub struct Hub {
    buffers: HashMap<u64, Vec<u64>>,
}

impl Hub {
    pub fn drain_all(&mut self) -> Vec<u64> {
        let mut out = Vec::new();
        for (_, v) in &mut self.buffers {
            out.extend(v.drain(..));
        }
        out
    }
}

pub fn sum(counts: HashMap<String, u64>) -> u64 {
    let mut total = 0;
    for v in counts.values() {
        total += v;
    }
    total
}
