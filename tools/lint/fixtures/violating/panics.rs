//! Violating: three non-test unwrap/expect calls against a frozen
//! budget of two.

pub fn run(lock: &std::sync::Mutex<u64>) -> u64 {
    let a = lock.lock().unwrap();
    let b = std::env::var("X").expect("X set by the harness");
    let c: u64 = b.parse().unwrap();
    *a + c
}
