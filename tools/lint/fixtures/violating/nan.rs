//! Violating: raw float comparison and a duplicate hand-rolled Ord
//! impl outside the nan home.

use std::cmp::Ordering;

pub struct Wrapped(pub f64);

impl PartialOrd for Wrapped {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        self.0.partial_cmp(&other.0)
    }
}

pub fn pick(a: f64, b: f64) -> f64 {
    if a.total_cmp(&b) == Ordering::Greater {
        a
    } else {
        b
    }
}
