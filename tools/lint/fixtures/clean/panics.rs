//! Clean: exactly at the frozen panic budget (3) in non-test code;
//! test-module unwraps do not count.

pub fn run(lock: &std::sync::Mutex<u64>) -> u64 {
    let a = lock.lock().unwrap();
    let b = std::env::var("X").expect("X set by the harness");
    let c: u64 = b.parse().unwrap();
    *a + c
}

#[cfg(test)]
mod tests {
    #[test]
    fn free_unwraps_in_tests() {
        let v: Option<u64> = Some(1);
        assert_eq!(v.unwrap(), 1);
        let w: Result<u64, ()> = Ok(2);
        assert_eq!(w.expect("ok"), 2);
    }
}
