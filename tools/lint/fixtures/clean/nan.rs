//! Clean: float ordering routed through the order module, no raw
//! comparisons anywhere.

pub fn best(xs: &[f64]) -> Option<f64> {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| crate::util::order::asc(*a, *b));
    sorted.last().copied()
}
