//! Clean: everything that LOOKS like a violation here is either inside
//! a comment, a string literal, or test-only code — the lexer must see
//! through all of it.

/* A block comment mentioning fs::write and Instant::now() is inert.
   /* Even when nested — partial_cmp, HashMap::new(), File::create. */
   Still inside the outer comment. */

pub const DOC: &str = "strings are opaque: Instant::now() fs::write partial_cmp";

pub const RAW: &str = r#"raw strings too: SystemTime::now() "File::create" OpenOptions"#;

pub fn lifetime_not_char<'a>(s: &'a str) -> &'a str {
    s
}

#[cfg(test)]
mod clocked {
    use std::time::Instant;

    #[test]
    fn timing_in_tests_is_fine() {
        let t = Instant::now();
        std::fs::write("/tmp/scratch", "test artifacts may write directly").unwrap();
        assert!(t.elapsed().as_secs() < 60);
    }
}

mod tests {
    pub fn bare_mod_tests_is_also_test_scope() {
        let _ = std::time::SystemTime::now();
    }
}
