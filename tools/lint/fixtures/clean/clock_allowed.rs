//! Clean: a wall-clock read excused by a justified site directive.

use std::time::Instant;

pub fn heartbeat_probe() -> Instant {
    // lint:allow(clock): worker heartbeat timestamps real elapsed time, not sim time
    Instant::now()
}
