//! Clean: this file IS the nan home — raw comparisons and the lawful
//! Ord impl are allowed to live here (and only here).

use std::cmp::Ordering;

pub fn asc(a: f64, b: f64) -> Ordering {
    match a.partial_cmp(&b) {
        Some(o) => o,
        None => a.is_nan().cmp(&b.is_nan()).reverse(),
    }
}

pub struct OrdF64(pub f64);

impl PartialEq for OrdF64 {
    fn eq(&self, other: &Self) -> bool {
        asc(self.0, other.0) == Ordering::Equal
    }
}

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(asc(self.0, other.0))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> Ordering {
        asc(self.0, other.0)
    }
}
