//! Clean: this file IS the durability home — the raw file-creation
//! primitives are its whole reason to exist.

use std::fs::{File, OpenOptions};
use std::io::Write;

pub fn write_atomic(path: &std::path::Path, text: &str) -> std::io::Result<()> {
    let tmp = path.with_extension("tmp");
    let mut f = File::create(&tmp)?;
    f.write_all(text.as_bytes())?;
    f.sync_all()?;
    std::fs::rename(&tmp, path)?;
    Ok(())
}

pub fn append_handle(path: &std::path::Path) -> std::io::Result<File> {
    OpenOptions::new().create(true).append(true).open(path)
}

pub fn overwrite(path: &std::path::Path, bytes: &[u8]) -> std::io::Result<()> {
    std::fs::write(path, bytes)
}
