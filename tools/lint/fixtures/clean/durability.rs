//! Clean: persistence goes through the atomic writer, never through
//! direct file creation.

pub fn save(path: &std::path::Path, text: &str) -> std::io::Result<()> {
    crate::coordinator::persist::write_atomic(path, text)
}
