//! Clean: in iteration scope, a hash map used for keyed lookup only,
//! plus one iteration excused by a justified site directive.

use std::collections::HashMap;

pub struct Index {
    by_id: HashMap<u64, String>,
}

impl Index {
    pub fn lookup(&self, id: u64) -> Option<&String> {
        self.by_id.get(&id)
    }

    pub fn shutdown_ids(&self) -> Vec<u64> {
        // lint:allow(hash_iteration): shutdown snapshot is sorted below, order never escapes
        let mut ids: Vec<u64> = self.by_id.keys().copied().collect();
        ids.sort_unstable();
        ids
    }
}
