"""L2: trial workloads as JAX compute graphs, calling the L1 Pallas kernels.

Two model families (the paper's trials are arbitrary training scripts; we
ship two representative ones):

  * MLP classifier   — the quickstart workload (grid search over lr x
    activation, mirroring the paper's §4.3 example).
  * Transformer LM   — the end-to-end model-selection workload (ASHA over
    lr / momentum / activation on a ~0.9M-param causal LM).

Each model exposes:
  init(seed)                      -> params               (list of arrays)
  loss_fn(params, *batch)         -> (loss, metrics_dict)

and `make_train_step` composes them into one fused fwd+bwd+SGD-momentum
update — the single jitted function that is AOT-lowered to HLO text and
executed from the rust runtime. Hyperparameters that trial schedulers
mutate at runtime (lr, momentum) are *runtime scalar inputs*, so one
compiled artifact serves every trial of a variant; the discrete
`activation` choice selects between compiled variants.

State layout: state = params + velocities (same shapes, velocities zero at
init). SGD-momentum: v' = mu * v + g ; p' = p - lr * v'.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels.attention import attention
from .kernels.fused_linear import fused_linear

# ---------------------------------------------------------------------------
# MLP classifier
# ---------------------------------------------------------------------------

MLP_DIMS = (32, 64, 64, 10)
MLP_BATCH = 64


def mlp_param_spec(dims=MLP_DIMS):
    spec = []
    for i in range(len(dims) - 1):
        spec.append((f"w{i}", (dims[i], dims[i + 1])))
        spec.append((f"b{i}", (dims[i + 1],)))
    return spec


def mlp_init(seed, dims=MLP_DIMS):
    key = jax.random.PRNGKey(seed)
    params = []
    for i in range(len(dims) - 1):
        key, wk = jax.random.split(key)
        scale = jnp.sqrt(2.0 / dims[i])
        params.append(jax.random.normal(wk, (dims[i], dims[i + 1]), jnp.float32) * scale)
        params.append(jnp.zeros((dims[i + 1],), jnp.float32))
    return params


def mlp_apply(params, x, activation):
    """Hidden layers use the fused Pallas kernel; the head is linear."""
    n_layers = len(params) // 2
    h = x
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        act = activation if i < n_layers - 1 else "linear"
        h = fused_linear(h, w, b, act)
    return h


def mlp_loss(params, x, y, activation):
    logits = mlp_apply(params, x, activation)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == y).astype(jnp.float32))
    return loss, {"accuracy": acc}


# ---------------------------------------------------------------------------
# Transformer language model
# ---------------------------------------------------------------------------

TLM_CONFIG = dict(vocab=128, d_model=128, n_heads=4, d_ff=256, n_layers=2, seq=64)
TLM_BATCH = 8


def tlm_param_spec(cfg=TLM_CONFIG):
    v, d, f, s = cfg["vocab"], cfg["d_model"], cfg["d_ff"], cfg["seq"]
    spec = [("embed", (v, d)), ("pos", (s, d))]
    for l in range(cfg["n_layers"]):
        spec += [
            (f"l{l}.ln1_s", (d,)), (f"l{l}.ln1_b", (d,)),
            (f"l{l}.wq", (d, d)), (f"l{l}.wk", (d, d)),
            (f"l{l}.wv", (d, d)), (f"l{l}.wo", (d, d)), (f"l{l}.bo", (d,)),
            (f"l{l}.ln2_s", (d,)), (f"l{l}.ln2_b", (d,)),
            (f"l{l}.wf1", (d, f)), (f"l{l}.bf1", (f,)),
            (f"l{l}.wf2", (f, d)), (f"l{l}.bf2", (d,)),
        ]
    spec += [("lnf_s", (d,)), ("lnf_b", (d,)), ("unembed", (d, v))]
    return spec


def tlm_init(seed, cfg=TLM_CONFIG):
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in tlm_param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_s"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b", ".bo", ".bf1", ".bf2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            scale = jnp.sqrt(1.0 / shape[0])
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
    return params


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale + bias


def tlm_apply(params, tokens, activation, cfg=TLM_CONFIG):
    """tokens: i32[B, S] -> logits f32[B, S, V]."""
    names = [n for n, _ in tlm_param_spec(cfg)]
    p = dict(zip(names, params))
    b, s = tokens.shape
    d, h = cfg["d_model"], cfg["n_heads"]
    dh = d // h
    x = p["embed"][tokens] + p["pos"][None, :s, :]
    zero_d = jnp.zeros((d,), jnp.float32)
    for l in range(cfg["n_layers"]):
        pre = f"l{l}."
        hx = _layer_norm(x, p[pre + "ln1_s"], p[pre + "ln1_b"])
        flat = hx.reshape(b * s, d)
        q = fused_linear(flat, p[pre + "wq"], zero_d, "linear")
        k = fused_linear(flat, p[pre + "wk"], zero_d, "linear")
        v = fused_linear(flat, p[pre + "wv"], zero_d, "linear")
        q = q.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        k = k.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, s, h, dh).transpose(0, 2, 1, 3)
        o = attention(q, k, v, True)
        o = o.transpose(0, 2, 1, 3).reshape(b * s, d)
        o = fused_linear(o, p[pre + "wo"], p[pre + "bo"], "linear")
        x = x + o.reshape(b, s, d)
        hx = _layer_norm(x, p[pre + "ln2_s"], p[pre + "ln2_b"]).reshape(b * s, d)
        ff = fused_linear(hx, p[pre + "wf1"], p[pre + "bf1"], activation)
        ff = fused_linear(ff, p[pre + "wf2"], p[pre + "bf2"], "linear")
        x = x + ff.reshape(b, s, d)
    x = _layer_norm(x, p["lnf_s"], p["lnf_b"])
    return jnp.einsum("bsd,dv->bsv", x, p["unembed"])


def tlm_loss(params, tokens, activation, cfg=TLM_CONFIG):
    """tokens: i32[B, S+1]; next-token cross-entropy over positions 0..S-1."""
    inp, tgt = tokens[:, :-1], tokens[:, 1:]
    logits = tlm_apply(params, inp, activation, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, tgt[:, :, None], axis=-1)[..., 0]
    loss = jnp.mean(nll)
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == tgt).astype(jnp.float32))
    return loss, {"accuracy": acc}


# ---------------------------------------------------------------------------
# Generic fused train step (fwd + bwd + SGD-momentum)
# ---------------------------------------------------------------------------

def make_train_step(loss_fn):
    """loss_fn(params, *batch) -> (loss, metrics). Returns
    train_step(params, velocities, batch, lr, momentum)
      -> (params', velocities', loss, metrics)."""

    def train_step(params, velocities, batch, lr, momentum):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, *batch)
        new_v = [momentum * v + g for v, g in zip(velocities, grads)]
        new_p = [p - lr * v for p, v in zip(params, new_v)]
        return new_p, new_v, loss, metrics

    return train_step


# ---------------------------------------------------------------------------
# Variant registry consumed by aot.py
# ---------------------------------------------------------------------------

def _mlp_loss_for(act):
    def f(params, x, y):
        return mlp_loss(params, x, y, act)
    return f


def _tlm_loss_for(act):
    def f(params, tokens):
        return tlm_loss(params, tokens, act)
    return f


def variants():
    """name -> dict(init, loss_fn, param_spec, batch_inputs, metrics, meta).

    batch_inputs: ordered [(name, shape, dtype-str)] fed after the state
    arrays; `lr` and `momentum` f32 scalars always follow the batch.
    """
    out = {}
    for act in ("relu", "tanh"):
        out[f"mlp_{act}"] = dict(
            init=mlp_init,
            loss_fn=_mlp_loss_for(act),
            param_spec=mlp_param_spec(),
            batch_inputs=[("x", (MLP_BATCH, MLP_DIMS[0]), "f32"),
                          ("y", (MLP_BATCH,), "i32")],
            metrics=["loss", "accuracy"],
            meta=dict(kind="mlp", activation=act, dims=list(MLP_DIMS),
                      batch=MLP_BATCH),
        )
    for act in ("gelu", "relu"):
        out[f"tlm_{act}"] = dict(
            init=tlm_init,
            loss_fn=_tlm_loss_for(act),
            param_spec=tlm_param_spec(),
            batch_inputs=[("tokens", (TLM_BATCH, TLM_CONFIG["seq"] + 1), "i32")],
            metrics=["loss", "accuracy"],
            meta=dict(kind="transformer_lm", activation=act, batch=TLM_BATCH,
                      **TLM_CONFIG),
        )
    return out
