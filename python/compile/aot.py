"""AOT lowering: JAX train/init functions -> HLO *text* artifacts + manifest.

HLO text (NOT `.serialize()`): jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which the rust side's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the HLO text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/gen_hlo.py).

For every model variant in model.variants() this writes:
    artifacts/<name>_train.hlo.txt   train_step_flat(state.., batch.., lr, mu)
                                       -> (state'.., loss, metric..)
    artifacts/<name>_init.hlo.txt    init_flat(seed: i32) -> (state..,)
and one artifacts/manifest.json describing, per variant, the exact state
array order/shapes/dtypes, batch inputs, scalar hyperparameter inputs, and
metric output names — everything the rust runtime needs to drive the
executables without ever importing python.

Run via `make artifacts`; python never runs on the request path.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

_DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_train_flat(variant):
    """Flat-signature train step: (*state, *batch, lr, momentum) -> tuple."""
    n = len(variant["param_spec"])
    nb = len(variant["batch_inputs"])
    step = M.make_train_step(variant["loss_fn"])
    metric_names = [m for m in variant["metrics"] if m != "loss"]

    def train_flat(*args):
        params = list(args[:n])
        vels = list(args[n:2 * n])
        batch = args[2 * n:2 * n + nb]
        lr, momentum = args[2 * n + nb], args[2 * n + nb + 1]
        new_p, new_v, loss, metrics = step(params, vels, batch, lr, momentum)
        extra = [metrics[m] for m in metric_names]
        return tuple(new_p + new_v + [loss] + extra)

    return train_flat


def build_init_flat(variant):
    def init_flat(seed):
        params = variant["init"](seed)
        vels = [jnp.zeros_like(p) for p in params]
        return tuple(params + vels)

    return init_flat


def example_args(variant):
    """ShapeDtypeStructs matching train_flat's signature."""
    state = [jax.ShapeDtypeStruct(shape, jnp.float32)
             for _, shape in variant["param_spec"]] * 2
    batch = [jax.ShapeDtypeStruct(shape, _DTYPES[dt])
             for _, shape, dt in variant["batch_inputs"]]
    scalars = [jax.ShapeDtypeStruct((), jnp.float32)] * 2
    return state + batch + scalars


def lower_variant(name, variant, outdir):
    train_path = os.path.join(outdir, f"{name}_train.hlo.txt")
    init_path = os.path.join(outdir, f"{name}_init.hlo.txt")

    lowered = jax.jit(build_train_flat(variant)).lower(*example_args(variant))
    with open(train_path, "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(build_init_flat(variant)).lower(
        jax.ShapeDtypeStruct((), jnp.int32))
    with open(init_path, "w") as f:
        f.write(to_hlo_text(lowered))

    spec = variant["param_spec"]
    n_params = sum(int(jnp.prod(jnp.array(s))) for _, s in spec)
    return {
        "train_hlo": os.path.basename(train_path),
        "init_hlo": os.path.basename(init_path),
        # state = params then velocities, identical shapes.
        "state": [{"name": n_, "shape": list(s)} for n_, s in spec],
        "batch_inputs": [{"name": n_, "shape": list(s), "dtype": dt}
                         for n_, s, dt in variant["batch_inputs"]],
        "scalars": ["lr", "momentum"],
        "metrics": variant["metrics"],
        "param_count": n_params,
        "meta": variant["meta"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/manifest.json",
                    help="manifest path; HLO files land beside it")
    ap.add_argument("--only", default=None,
                    help="comma-separated variant names (default: all)")
    args = ap.parse_args()
    outdir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(outdir, exist_ok=True)

    manifest = {"models": {}}
    names = args.only.split(",") if args.only else None
    for name, variant in M.variants().items():
        if names and name not in names:
            continue
        print(f"lowering {name} ...", flush=True)
        manifest["models"][name] = lower_variant(name, variant, outdir)

    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    sizes = {m: os.path.getsize(os.path.join(outdir, v["train_hlo"]))
             for m, v in manifest["models"].items()}
    print(f"wrote {args.out}; train HLO sizes: {sizes}")


if __name__ == "__main__":
    main()
