"""L1 Pallas kernel: fused linear layer — act(x @ w + b).

The compute hot-spot of every trial workload (MLP layers and the
transformer FFN). The kernel tiles the (M, K) x (K, N) matmul into
VMEM-sized blocks via BlockSpec and fuses bias-add + activation into the
epilogue, saving one HBM round-trip versus matmul -> act (the TPU analogue
of a CUDA threadblock epilogue).

TPU mapping (see DESIGN.md §Hardware-Adaptation): each grid step holds a
(bm, K) x (K, bn) panel pair plus a (bm, bn) accumulator in VMEM; the inner
jnp.dot targets the 128x128 MXU with preferred_element_type=float32.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered through the Pallas interpreter into
plain HLO (validated against ref.fused_linear_ref).

Gradients: pallas_call has no general VJP rule, so the public entry point
`fused_linear` is a jax.custom_vjp whose forward runs the kernel and whose
backward uses the exact jnp math — gradients are exact and the kernel
stays on the forward (hot) path of the lowered HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _pick_block(dim, preferred):
    """Largest divisor of `dim` that is <= preferred (keeps grids exact)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def _kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    """One (bm, bn) output tile: act(x_tile @ w_tile + b_tile)."""
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    o_ref[...] = _ref.ACTIVATIONS[activation](acc)


def fused_linear_kernel(x, w, b, activation="linear", block_m=128, block_n=128):
    """Raw pallas_call (no custom_vjp). Exposed for the pytest sweeps."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        functools.partial(_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_linear(x, w, b, activation="linear"):
    """act(x @ w + b) with the Pallas kernel on the forward path."""
    return fused_linear_kernel(x, w, b, activation)


def _fwd(x, w, b, activation):
    y = fused_linear_kernel(x, w, b, activation)
    return y, (x, w, b)


def _bwd(activation, res, g):
    x, w, b = res
    # Recompute pre-activation with the jnp oracle; differentiate exactly.
    _, vjp = jax.vjp(lambda x_, w_, b_: _ref.fused_linear_ref(x_, w_, b_, activation), x, w, b)
    return vjp(g)


fused_linear.defvjp(_fwd, _bwd)
