"""L1 Pallas kernel: causal scaled-dot-product attention.

One grid step per (batch, head): the full (S, D) q/k/v panels sit in VMEM
(S=64, D=32 in the shipped transformer -> 3 * 8 KiB panels + an (S, S)
score tile = 24 KiB, far under the 16 MiB VMEM budget), the score matmul
and the probability @ v matmul both target the MXU, and masking + a
numerically-stable softmax run in the epilogue between them — the
flash-attention insight (never materialize scores in HBM) expressed with
BlockSpec instead of threadblocks.

interpret=True (CPU PJRT cannot run Mosaic custom-calls); exact-gradient
custom_vjp via the jnp oracle, same pattern as fused_linear.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref as _ref


def _kernel(q_ref, k_ref, v_ref, o_ref, *, causal):
    q = q_ref[0]  # (S, D)
    k = k_ref[0]
    v = v_ref[0]
    s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        row = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        scores = jnp.where(row >= col, scores, -1e30)
    # Numerically stable softmax in-register (never hits HBM).
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


def attention_kernel(q, k, v, causal=True):
    """Raw pallas_call over a (B*H,) grid. Exposed for the pytest sweeps."""
    b, h, s, d = q.shape
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal),
        grid=(b * h,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), jnp.float32),
        interpret=True,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def attention(q, k, v, causal=True):
    """Causal attention with the Pallas kernel on the forward path."""
    return attention_kernel(q, k, v, causal)


def _fwd(q, k, v, causal):
    return attention_kernel(q, k, v, causal), (q, k, v)


def _bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: _ref.attention_ref(q_, k_, v_, causal), q, k, v)
    return vjp(g)


attention.defvjp(_fwd, _bwd)
