"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
must match its oracle to float32 tolerance under pytest (see
python/tests/test_kernel.py). They are also used as the backward pass of
the custom_vjp wrappers, so gradients are exact regardless of kernel
implementation details.
"""

import jax
import jax.numpy as jnp

ACTIVATIONS = {
    "linear": lambda x: x,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
    "gelu": jax.nn.gelu,
}


def fused_linear_ref(x, w, b, activation="linear"):
    """Oracle for kernels.fused_linear: act(x @ w + b).

    x: f32[M, K], w: f32[K, N], b: f32[N] -> f32[M, N]
    """
    act = ACTIVATIONS[activation]
    return act(jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :])


def attention_ref(q, k, v, causal=True):
    """Oracle for kernels.attention: scaled dot-product attention.

    q, k, v: f32[B, H, S, D] -> f32[B, H, S, D]
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", p, v)
