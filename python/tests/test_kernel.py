"""Kernel-vs-oracle correctness: the CORE numeric signal of the L1 layer.

hypothesis sweeps shapes (and block sizes) of the Pallas kernels and
asserts allclose against the pure-jnp oracles in kernels.ref; explicit
parametrized cases pin the exact shapes shipped in the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention, attention_kernel
from compile.kernels.fused_linear import fused_linear, fused_linear_kernel

ACTS = ["linear", "relu", "tanh", "gelu"]


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, lo, hi)


# ---------------------------------------------------------------------------
# fused_linear
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("act", ACTS)
@pytest.mark.parametrize("m,k,n", [(8, 32, 16), (64, 32, 64), (512, 128, 128),
                                   (1, 4, 1), (3, 5, 7)])
def test_fused_linear_matches_ref(act, m, k, n):
    x, w, b = rand(0, (m, k)), rand(1, (k, n)), rand(2, (n,))
    got = fused_linear_kernel(x, w, b, act)
    want = ref.fused_linear_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 96), k=st.integers(1, 64), n=st.integers(1, 96),
    act=st.sampled_from(ACTS),
    bm=st.integers(1, 128), bn=st.integers(1, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_fused_linear_hypothesis(m, k, n, act, bm, bn, seed):
    x, w, b = rand(seed, (m, k)), rand(seed + 1, (k, n)), rand(seed + 2, (n,))
    got = fused_linear_kernel(x, w, b, act, block_m=bm, block_n=bn)
    want = ref.fused_linear_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("act", ACTS)
def test_fused_linear_grads_match_ref(act):
    x, w, b = rand(3, (16, 24)), rand(4, (24, 12)), rand(5, (12,))

    def loss_kernel(x, w, b):
        return jnp.sum(fused_linear(x, w, b, act) ** 2)

    def loss_ref(x, w, b):
        return jnp.sum(ref.fused_linear_ref(x, w, b, act) ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)


def test_fused_linear_jit_and_vmap_compose():
    x, w, b = rand(6, (8, 8)), rand(7, (8, 8)), rand(8, (8,))
    got = jax.jit(lambda x: fused_linear(x, w, b, "relu"))(x)
    np.testing.assert_allclose(got, ref.fused_linear_ref(x, w, b, "relu"),
                               rtol=1e-5, atol=1e-5)


def test_fused_linear_rejects_bad_shapes():
    with pytest.raises(Exception):
        fused_linear_kernel(rand(0, (4, 5)), rand(1, (6, 7)), rand(2, (7,)))


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("b,h,s,d", [(1, 1, 4, 4), (2, 4, 16, 8),
                                     (8, 4, 64, 32), (1, 2, 7, 5)])
def test_attention_matches_ref(causal, b, h, s, d):
    q, k, v = rand(0, (b, h, s, d)), rand(1, (b, h, s, d)), rand(2, (b, h, s, d))
    got = attention_kernel(q, k, v, causal)
    want = ref.attention_ref(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4), h=st.integers(1, 4),
    s=st.integers(1, 32), d=st.integers(1, 16),
    causal=st.booleans(), seed=st.integers(0, 2**31 - 1),
)
def test_attention_hypothesis(b, h, s, d, causal, seed):
    q = rand(seed, (b, h, s, d))
    k = rand(seed + 1, (b, h, s, d))
    v = rand(seed + 2, (b, h, s, d))
    got = attention_kernel(q, k, v, causal)
    want = ref.attention_ref(q, k, v, causal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_causality():
    """Future tokens must not influence past outputs."""
    b, h, s, d = 1, 2, 8, 4
    q, k, v = rand(0, (b, h, s, d)), rand(1, (b, h, s, d)), rand(2, (b, h, s, d))
    out1 = attention_kernel(q, k, v, True)
    # Perturb the last key/value: outputs at positions < s-1 must not change.
    k2 = k.at[:, :, -1].add(100.0)
    v2 = v.at[:, :, -1].add(100.0)
    out2 = attention_kernel(q, k2, v2, True)
    np.testing.assert_allclose(out1[:, :, :-1], out2[:, :, :-1], rtol=1e-5, atol=1e-6)
    assert not np.allclose(out1[:, :, -1], out2[:, :, -1])


def test_attention_grads_match_ref():
    b, h, s, d = 2, 2, 8, 4
    q, k, v = rand(3, (b, h, s, d)), rand(4, (b, h, s, d)), rand(5, (b, h, s, d))

    g1 = jax.grad(lambda q, k, v: jnp.sum(attention(q, k, v, True) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(ref.attention_ref(q, k, v, True) ** 2),
                  argnums=(0, 1, 2))(q, k, v)
    for a, e in zip(g1, g2):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)


def test_attention_softmax_stability():
    """Large logits must not overflow (stable softmax in the kernel)."""
    b, h, s, d = 1, 1, 8, 4
    q = rand(0, (b, h, s, d)) * 100.0
    k = rand(1, (b, h, s, d)) * 100.0
    v = rand(2, (b, h, s, d))
    out = attention_kernel(q, k, v, True)
    assert np.all(np.isfinite(np.asarray(out)))
