"""L2 model tests: shapes, loss behaviour, train-step semantics, and the
flat AOT signatures consumed by the rust runtime."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M


def mlp_batch(seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (M.MLP_BATCH, M.MLP_DIMS[0]))
    y = jax.random.randint(jax.random.PRNGKey(seed + 1), (M.MLP_BATCH,), 0, M.MLP_DIMS[-1])
    return x, y


def tlm_batch(seed=0):
    return (jax.random.randint(jax.random.PRNGKey(seed),
                               (M.TLM_BATCH, M.TLM_CONFIG["seq"] + 1),
                               0, M.TLM_CONFIG["vocab"]),)


# ---------------------------------------------------------------------------
# init / apply shapes
# ---------------------------------------------------------------------------

def test_mlp_init_matches_spec():
    params = M.mlp_init(0)
    spec = M.mlp_param_spec()
    assert len(params) == len(spec)
    for p, (name, shape) in zip(params, spec):
        assert p.shape == shape, name
        assert p.dtype == jnp.float32


def test_tlm_init_matches_spec():
    params = M.tlm_init(0)
    spec = M.tlm_param_spec()
    assert len(params) == len(spec)
    for p, (name, shape) in zip(params, spec):
        assert p.shape == shape, name


def test_init_seeds_differ():
    a, b = M.mlp_init(0), M.mlp_init(1)
    assert not np.allclose(a[0], b[0])
    a, b = M.tlm_init(0), M.tlm_init(7)
    assert not np.allclose(a[0], b[0])


def test_mlp_apply_shape():
    logits = M.mlp_apply(M.mlp_init(0), mlp_batch()[0], "relu")
    assert logits.shape == (M.MLP_BATCH, M.MLP_DIMS[-1])


def test_tlm_apply_shape():
    toks = tlm_batch()[0][:, :-1]
    logits = M.tlm_apply(M.tlm_init(0), toks, "gelu")
    assert logits.shape == (M.TLM_BATCH, M.TLM_CONFIG["seq"], M.TLM_CONFIG["vocab"])


# ---------------------------------------------------------------------------
# loss semantics
# ---------------------------------------------------------------------------

def test_mlp_initial_loss_near_uniform():
    loss, _ = M.mlp_loss(M.mlp_init(0), *mlp_batch(), "relu")
    assert abs(float(loss) - np.log(M.MLP_DIMS[-1])) < 0.7


def test_tlm_initial_loss_near_uniform():
    loss, _ = M.tlm_loss(M.tlm_init(0), tlm_batch()[0], "gelu")
    assert abs(float(loss) - np.log(M.TLM_CONFIG["vocab"])) < 1.0


@pytest.mark.parametrize("variant", ["mlp_relu", "mlp_tanh"])
def test_mlp_train_step_decreases_loss(variant):
    var = M.variants()[variant]
    step = M.make_train_step(var["loss_fn"])
    params = var["init"](0)
    vels = [jnp.zeros_like(p) for p in params]
    batch = mlp_batch()
    first = None
    for _ in range(15):
        params, vels, loss, _ = step(params, vels, batch, 0.1, 0.9)
        first = first if first is not None else float(loss)
    assert float(loss) < first * 0.5


def test_tlm_train_step_decreases_loss():
    var = M.variants()["tlm_gelu"]
    step = jax.jit(lambda p, v, b, lr, mu: M.make_train_step(var["loss_fn"])(p, v, b, lr, mu))
    params = var["init"](0)
    vels = [jnp.zeros_like(p) for p in params]
    batch = tlm_batch()
    first = None
    for _ in range(10):
        params, vels, loss, _ = step(params, vels, batch, 0.1, 0.9)
        first = first if first is not None else float(loss)
    assert float(loss) < first


def test_momentum_zero_equals_sgd():
    var = M.variants()["mlp_relu"]
    step = M.make_train_step(var["loss_fn"])
    params = var["init"](0)
    vels = [jnp.zeros_like(p) for p in params]
    batch = mlp_batch()
    (loss, _), grads = jax.value_and_grad(var["loss_fn"], has_aux=True)(params, *batch)
    new_p, new_v, _, _ = step(params, vels, batch, 0.05, 0.0)
    for p, g, np_ in zip(params, grads, new_p):
        np.testing.assert_allclose(np_, p - 0.05 * g, rtol=1e-6, atol=1e-7)


def test_lr_is_runtime_input():
    """Same compiled step, different lr scalars -> different updates."""
    var = M.variants()["mlp_relu"]
    step = M.make_train_step(var["loss_fn"])
    params = var["init"](0)
    vels = [jnp.zeros_like(p) for p in params]
    batch = mlp_batch()
    a, _, _, _ = step(params, vels, batch, 0.01, 0.9)
    b, _, _, _ = step(params, vels, batch, 0.5, 0.9)
    assert not np.allclose(a[0], b[0])


# ---------------------------------------------------------------------------
# flat AOT signatures (what rust actually calls)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(M.variants().keys()))
def test_flat_train_signature(name):
    var = M.variants()[name]
    flat = aot.build_train_flat(var)
    n = len(var["param_spec"])
    params = var["init"](0)
    vels = [jnp.zeros_like(p) for p in params]
    batch = mlp_batch() if var["meta"]["kind"] == "mlp" else tlm_batch()
    out = flat(*params, *vels, *batch, jnp.float32(0.1), jnp.float32(0.9))
    assert len(out) == 2 * n + len(var["metrics"])
    for o, p in zip(out[:n], params):
        assert o.shape == p.shape
    loss = out[2 * n]
    assert loss.shape == ()


@pytest.mark.parametrize("name", list(M.variants().keys()))
def test_flat_init_signature(name):
    var = M.variants()[name]
    flat = aot.build_init_flat(var)
    out = flat(jnp.int32(3))
    n = len(var["param_spec"])
    assert len(out) == 2 * n
    for v in out[n:]:
        assert float(jnp.abs(v).sum()) == 0.0  # velocities start at zero


def test_example_args_match_flat():
    var = M.variants()["mlp_relu"]
    args = aot.example_args(var)
    assert len(args) == 2 * len(var["param_spec"]) + len(var["batch_inputs"]) + 2


def test_flat_train_is_lowerable():
    var = M.variants()["mlp_relu"]
    lowered = jax.jit(aot.build_train_flat(var)).lower(*aot.example_args(var))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and len(text) > 1000
