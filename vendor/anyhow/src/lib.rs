//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The container this repository builds in has no network access to
//! crates.io, so the handful of `anyhow` features the codebase uses are
//! reimplemented here behind the same names:
//!
//! * [`Error`] — an opaque error value holding a chain of context
//!   messages. `{}` prints the outermost message; `{:#}` prints the full
//!   chain separated by `": "` (matching upstream's alternate formatting).
//! * [`Result`] — `Result<T, Error>` with a defaulted error type.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`, including results that already carry an [`Error`].
//! * `?` conversion from any `std::error::Error + Send + Sync + 'static`.

use std::fmt;

/// An error chain: `messages[0]` is the outermost (most recent) context,
/// the last element is the root cause.
pub struct Error {
    messages: Vec<String>,
}

impl Error {
    /// Construct an error from a printable root cause.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { messages: vec![message.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.messages.insert(0, context.to_string());
        self
    }

    /// The root cause message (innermost entry of the chain).
    pub fn root_cause(&self) -> &str {
        self.messages.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, outermost first — "ctx: ctx: cause".
            write!(f, "{}", self.messages.join(": "))
        } else {
            write!(f, "{}", self.messages.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirrors upstream's Debug: message plus a caused-by list.
        write!(f, "{}", self.messages.first().map(String::as_str).unwrap_or(""))?;
        if self.messages.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.messages[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does NOT implement `std::error::Error`, exactly as
// upstream: that keeps this blanket conversion coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Sealed helper: anything `Context` can treat as an error value.
pub trait IntoError: private::Sealed {
    /// Convert into an [`Error`] chain.
    fn into_error(self) -> Error;
}

impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
    fn into_error(self) -> Error {
        Error::msg(self)
    }
}

impl IntoError for Error {
    fn into_error(self) -> Error {
        self
    }
}

mod private {
    pub trait Sealed {}
    impl<E: std::error::Error + Send + Sync + 'static> Sealed for E {}
    impl Sealed for super::Error {}
}

/// Extension trait adding context to `Result` and `Option` values.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_show_chain() {
        let e: Error = Err::<(), _>(io_err()).with_context(|| "reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn context_stacks_on_existing_error() {
        let inner: Result<()> = Err(anyhow!("cause"));
        let outer = inner.context("outer").unwrap_err();
        assert_eq!(format!("{outer:#}"), "outer: cause");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(format!("{}", v.context("nothing there").unwrap_err()), "nothing there");
        assert_eq!(Some(3u32).context("unused").unwrap(), 3);
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<String> {
            let s = std::str::from_utf8(&[0xff])?;
            Ok(s.to_string())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative: {x}");
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative: -1");
        assert_eq!(format!("{}", f(11).unwrap_err()), "too big: 11");
    }
}
