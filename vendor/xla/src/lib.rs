//! Offline stub of the `xla` crate (the PJRT bindings the runtime layer
//! compiles against).
//!
//! The build container has no network access and no XLA shared library,
//! so this crate provides the exact API surface `tune::runtime` uses:
//!
//! * a **functional** [`Literal`] host-data model (scalars, rank-N f32/i32
//!   arrays, tuples) — construction, reshape, readback all work, so state
//!   serialization code paths are fully testable without a backend;
//! * **stubbed execution**: [`PjRtClient::cpu`] and
//!   [`HloModuleProto::from_text_file`] return a descriptive [`Error`].
//!   Callers that gate on artifacts being present (all of them in this
//!   repository) skip gracefully.
//!
//! Swapping in a real backend means replacing this path dependency with
//! the real `xla` crate; no call sites change.

use std::fmt;
use std::path::Path;

/// Error type for all stub operations. Implements `std::error::Error` so
/// `?` converts it into `anyhow::Error` at call sites.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias used throughout the stub.
pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

const NO_BACKEND: &str = "offline stub has no XLA backend; link the real xla crate (and run `make artifacts`) to execute HLO";

/// Element types the runtime layer exchanges with executables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    /// 32-bit IEEE float.
    F32,
    /// 32-bit signed integer.
    S32,
}

/// Shape of an array literal: element type + dimensions.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents, outermost first.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element type of the array.
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

#[derive(Clone, Debug)]
enum Data {
    F32(Vec<f32>),
    S32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// Host-side literal value: a typed array or a tuple of literals.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Rust scalar types that map onto XLA element types.
pub trait NativeType: Copy {
    /// The corresponding XLA element type.
    const TY: ElementType;
    /// Wrap a host vector as literal storage.
    fn wrap(v: Vec<Self>) -> Data;
    /// Extract a host vector from literal storage.
    fn unwrap(d: &Data) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn wrap(v: Vec<Self>) -> Data {
        Data::F32(v)
    }
    fn unwrap(d: &Data) -> Result<Vec<Self>> {
        match d {
            Data::F32(v) => Ok(v.clone()),
            _ => err("literal is not f32"),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn wrap(v: Vec<Self>) -> Data {
        Data::S32(v)
    }
    fn unwrap(d: &Data) -> Result<Vec<Self>> {
        match d {
            Data::S32(v) => Ok(v.clone()),
            _ => err("literal is not i32"),
        }
    }
}

impl Literal {
    /// Rank-0 literal from one scalar.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { data: T::wrap(vec![v]), dims: Vec::new() }
    }

    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { data: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Tuple literal from parts.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { data: Data::Tuple(parts), dims: Vec::new() }
    }

    /// Reinterpret the array with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let have = match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::S32(v) => v.len() as i64,
            Data::Tuple(_) => return err("cannot reshape a tuple literal"),
        };
        if n != have {
            return err(format!("reshape {dims:?} wants {n} elements, literal has {have}"));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => err("literal is not a tuple"),
        }
    }

    /// First element of an array literal, converted to `T`.
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        T::unwrap(&self.data)?.first().copied().map_or_else(|| err("empty literal"), Ok)
    }

    /// Full host readback of an array literal.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data)
    }

    /// Shape of an array literal (error on tuples).
    pub fn array_shape(&self) -> Result<ArrayShape> {
        let ty = self.ty()?;
        Ok(ArrayShape { ty, dims: self.dims.clone() })
    }

    /// Element type of an array literal (error on tuples).
    pub fn ty(&self) -> Result<ElementType> {
        match &self.data {
            Data::F32(_) => Ok(ElementType::F32),
            Data::S32(_) => Ok(ElementType::S32),
            Data::Tuple(_) => err("tuple literal has no element type"),
        }
    }
}

/// Parsed HLO module (stub: never constructible offline).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file. Always errors in the offline stub.
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        err(NO_BACKEND)
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle returned by execution.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Unreachable offline (no
    /// execution can produce a buffer), kept for API parity.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        err(NO_BACKEND)
    }
}

/// A compiled executable. Never constructible offline.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments. Unreachable offline.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err(NO_BACKEND)
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client. Always errors in the offline stub, with a
    /// message explaining how to get a real backend.
    pub fn cpu() -> Result<PjRtClient> {
        err(NO_BACKEND)
    }

    /// Platform name of the backing runtime.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Always errors in the offline stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(NO_BACKEND)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.ty().unwrap(), ElementType::F32);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
    }

    #[test]
    fn literal_scalar_and_tuple() {
        let s = Literal::scalar(7i32);
        assert_eq!(s.get_first_element::<i32>().unwrap(), 7);
        let t = Literal::tuple(vec![s, Literal::scalar(1.5f32)]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1].get_first_element::<f32>().unwrap(), 1.5);
    }

    #[test]
    fn reshape_checks_element_count() {
        assert!(Literal::vec1(&[1.0f32, 2.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn type_mismatch_errors() {
        let l = Literal::vec1(&[1i32]);
        assert!(l.to_vec::<f32>().is_err());
    }

    #[test]
    fn backend_entry_points_error_clearly() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("offline stub"));
        assert!(HloModuleProto::from_text_file("/tmp/x.hlo").is_err());
    }
}
